"""Read-optimized immutable UIH store (paper §4.1.2).

Single-level layout: each user's long-term history is partitioned into
fixed-length temporal *stripes* keyed by the multi-dimensional composite key
``(user_id, feature_group, subsequence_start_ts)``. Stripes are produced
pre-sorted by the offloaded compaction pipeline and **bulk-loaded** as a whole
generation — there is no write path other than ``bulk_load``, hence no LSM
multi-level read amplification and no compaction-induced write amplification.

The read path is a bounded *multi-range scan*: for each request the store
locates the stripe run overlapping ``[start_ts, end_ts]`` (one "seek") and then
reads stripes sequentially. Projection pushdown happens server-side in three
dimensions (§4.1.2):

  1. sequence-length projection — scan only as many stripes (from the most
     recent backwards) as needed for the tenant's ``max_events``;
  2. feature-group projection — the composite key isolates groups physically;
  3. trait projection — selective byte-level decoding inside a stripe.

Batched reads are *planned* (§4.2.3, "optimized multi-range scan with parallel
I/O"). ``plan()`` dedupes identical ``(user_id, group, bounds, max_events,
traits)`` requests and groups the survivors by shard; ``execute_plan()`` then
runs the shard groups concurrently on a thread pool, charging the
``latency_model`` once per shard (parallel remote I/O) instead of once for the
whole batch, and decoding each stripe blob at most once per batch via the
``columnar.StripeDecodeCache`` LRU. ``IOStats`` exposes the plan's work
savings: ``dedup_hits`` (requests answered by an identical in-batch twin),
``decode_cache_hits`` (stripe decodes skipped), and ``parallel_shards``
(cumulative shard fanout executed concurrently by batched scans).
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import events as ev
from repro.storage import columnar
from repro.storage.sharding import ShardRouter


@dataclasses.dataclass(frozen=True)
class Stripe:
    start_ts: int
    end_ts: int
    n_events: int
    blob: bytes


@dataclasses.dataclass(frozen=True)
class ScanRequest:
    user_id: int
    group: str
    start_ts: int            # inclusive temporal lower bound (version metadata)
    end_ts: int              # inclusive temporal upper bound (version metadata)
    max_events: int = -1     # sequence-length projection (-1 = unbounded)
    traits: Optional[Tuple[str, ...]] = None  # trait projection (None = group's all)


@dataclasses.dataclass
class IOStats:
    seeks: int = 0
    stripes_read: int = 0
    bytes_scanned: int = 0    # stripe blob bytes touched (I/O)
    bytes_decoded: int = 0    # payload bytes actually decoded (selective decode)
    requests: int = 0         # scans actually executed (post-dedupe)
    batched_requests: int = 0
    dedup_hits: int = 0         # requests answered by an identical in-plan twin
    decode_cache_hits: int = 0  # stripe decodes served from the decode LRU
    parallel_shards: int = 0    # cumulative shard fanout of batched executions

    def snapshot(self) -> "IOStats":
        return dataclasses.replace(self)

    def delta(self, since: "IOStats") -> "IOStats":
        return IOStats(*(getattr(self, f.name) - getattr(since, f.name)
                         for f in dataclasses.fields(IOStats)))

    def merge(self, other: "IOStats") -> None:
        for f in dataclasses.fields(IOStats):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))


@dataclasses.dataclass
class ScanPlan:
    """Deduped, shard-grouped execution plan for a batch of scan requests."""

    unique: List[ScanRequest]          # deduped requests, first-seen order
    assignment: List[int]              # original request idx -> unique idx
    shard_groups: Dict[int, List[int]]  # shard -> indices into ``unique``

    @property
    def dedup_hits(self) -> int:
        return len(self.assignment) - len(self.unique)

    @property
    def fanout(self) -> int:
        return len(self.shard_groups)


class ImmutableUIHStore:
    def __init__(
        self,
        schema: Optional[ev.TraitSchema] = None,
        n_shards: int = 8,
        decode_cache_size: int = 256,
    ):
        self.schema = schema or ev.default_schema()
        self.router = ShardRouter(n_shards)
        # shard -> (user_id, group) -> (sorted start_ts list, stripes list)
        self._shards: List[Dict[Tuple[int, str], Tuple[List[int], List[Stripe]]]] = [
            {} for _ in range(n_shards)
        ]
        self.generation = -1
        self.stats = IOStats()
        self.bulk_load_bytes = 0
        # Optional remote-I/O latency emulation for DPP benchmarks:
        # callable(seeks, bytes_scanned, shard_fanout) -> seconds to sleep.
        # Batched execution charges it once per shard group (parallel I/O).
        self.latency_model = None
        self.decode_cache = (
            columnar.StripeDecodeCache(decode_cache_size)
            if decode_cache_size > 0 else None
        )
        self._stats_lock = threading.Lock()
        # eager: an idle executor spawns no threads until first submit, and
        # eager construction avoids double-create races on first batched scan
        self._pool = ThreadPoolExecutor(
            max_workers=min(n_shards, 16), thread_name_prefix="uih-scan"
        )

    # -- bulk load (write path) ---------------------------------------------
    def bulk_load(
        self,
        tables: Dict[Tuple[int, str], List[Stripe]],
        generation: int,
    ) -> None:
        """Replace the store contents with a new compaction generation.

        ``tables`` maps (user_id, group) -> chronologically ordered stripes.
        Pre-sorted input is *required* (compaction guarantees it); the store
        only verifies and installs — mirroring a bulk file ingest."""
        new_shards: List[Dict[Tuple[int, str], Tuple[List[int], List[Stripe]]]] = [
            {} for _ in self._shards
        ]
        load_bytes = 0
        for (user_id, group), stripes in tables.items():
            starts = [s.start_ts for s in stripes]
            assert starts == sorted(starts), "compaction must emit sorted stripes"
            shard = self.router.route(user_id)
            new_shards[shard][(user_id, group)] = (starts, list(stripes))
            load_bytes += sum(len(s.blob) for s in stripes)
        self._shards = new_shards
        self.generation = generation
        self.bulk_load_bytes += load_bytes

    # -- read path ------------------------------------------------------------
    def _locate(self, user_id: int, group: str):
        shard = self.router.route(user_id)
        return shard, self._shards[shard].get((user_id, group))

    def _decode(self, s: Stripe, traits, stats: IOStats) -> ev.EventBatch:
        if self.decode_cache is None:
            stats.bytes_decoded += columnar.decoded_bytes_for(s.blob, traits)
            return columnar.decode_stripe(s.blob, self.schema, traits)
        batch, hit = self.decode_cache.get(s.blob, self.schema, traits)
        if hit:
            stats.decode_cache_hits += 1
        else:
            stats.bytes_decoded += columnar.decoded_bytes_for(s.blob, traits)
        return batch

    def _scan_into(self, req: ScanRequest, stats: IOStats) -> ev.EventBatch:
        """Execute one range scan, accounting I/O into ``stats`` (the batched
        executor passes per-shard accumulators so shard threads don't race)."""
        stats.requests += 1
        traits = req.traits or self.schema.group_traits(req.group)
        shard, entry = self._locate(req.user_id, req.group)
        if entry is None:
            return ev.empty_batch(self.schema, traits)
        starts, stripes = entry
        stats.seeks += 1  # single-level layout: one seek per (user,group) run

        # stripe run overlapping [start_ts, end_ts]
        lo = bisect.bisect_right(starts, req.start_ts) - 1
        lo = max(lo, 0)
        hi = bisect.bisect_right(starts, req.end_ts)  # stripes[lo:hi] may overlap
        if lo >= hi:
            return ev.empty_batch(self.schema, traits)

        # sequence-length projection: walk backwards from the most recent stripe
        chosen: List[Stripe] = []
        have = 0
        for i in range(hi - 1, lo - 1, -1):
            s = stripes[i]
            if s.end_ts < req.start_ts:
                break
            chosen.append(s)
            # conservative count: events in stripe within bound (upper estimate)
            have += s.n_events
            if req.max_events >= 0 and have >= req.max_events + s.n_events:
                # we may overshoot by up to one stripe at each temporal edge;
                # an extra stripe guards against end_ts trimming removing events
                break
        chosen.reverse()

        parts: List[ev.EventBatch] = []
        for s in chosen:
            stats.stripes_read += 1
            stats.bytes_scanned += len(s.blob)
            parts.append(self._decode(s, traits, stats))
        out = ev.concat_batches(parts)
        if not out:
            return ev.empty_batch(self.schema, traits)
        out = ev.time_slice(out, req.start_ts, req.end_ts)
        if req.max_events >= 0 and ev.batch_len(out) > req.max_events:
            # keep the most recent max_events (tenant sequence-length budget)
            n = ev.batch_len(out)
            out = ev.slice_batch(out, n - req.max_events, n)
        return out

    def scan(self, req: ScanRequest) -> ev.EventBatch:
        """Bounded range scan with 3-dimensional projection pushdown."""
        return self._scan_into(req, self.stats)

    # -- planned batch execution ----------------------------------------------
    def plan(self, reqs: Sequence[ScanRequest]) -> ScanPlan:
        """Dedupe identical requests and group the survivors by shard."""
        index: Dict[ScanRequest, int] = {}
        unique: List[ScanRequest] = []
        assignment: List[int] = []
        shard_groups: Dict[int, List[int]] = {}
        for r in reqs:
            j = index.get(r)
            if j is None:
                j = index[r] = len(unique)
                unique.append(r)
                shard_groups.setdefault(self.router.route(r.user_id), []).append(j)
            assignment.append(j)
        return ScanPlan(unique=unique, assignment=assignment,
                        shard_groups=shard_groups)

    def close(self) -> None:
        """Shut down the shard-scan thread pool (idempotent). Long-lived
        processes that churn through stores should close them (or use the
        store as a context manager); short-lived ones can rely on interpreter
        exit — an unused pool never spawns threads."""
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "ImmutableUIHStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def execute_plan(
        self, plan: ScanPlan, out_stats: Optional[IOStats] = None
    ) -> List[ev.EventBatch]:
        """Run a plan's shard groups concurrently; results in original request
        order (deduped requests share one execution).

        ``out_stats``: optional caller-owned accumulator that receives this
        call's delta as well — the global ``self.stats`` is shared across all
        callers, so a concurrent caller cannot attribute snapshot/delta
        windows of it to its own traffic."""
        results: List[Optional[ev.EventBatch]] = [None] * len(plan.unique)

        def run_shard(group: List[int]) -> IOStats:
            local = IOStats()
            for j in group:
                results[j] = self._scan_into(plan.unique[j], local)
            if self.latency_model is not None:
                # each shard pays its own I/O latency (plus the batch's
                # cross-shard coordination term); shards overlap, so the
                # batch's wall time is the max over shards, not the sum
                delay = self.latency_model(local.seeks, local.bytes_scanned,
                                           plan.fanout)
                if delay > 0:
                    time.sleep(delay)
            return local

        groups = list(plan.shard_groups.values())
        if len(groups) <= 1:
            shard_stats = [run_shard(g) for g in groups]
        else:
            shard_stats = list(self._pool.map(run_shard, groups))
        call = IOStats(batched_requests=1, dedup_hits=plan.dedup_hits,
                       parallel_shards=plan.fanout)
        for local in shard_stats:
            call.merge(local)
        with self._stats_lock:
            self.stats.merge(call)
        if out_stats is not None:
            out_stats.merge(call)
        return [results[j] for j in plan.assignment]

    def multi_range_scan(
        self,
        reqs: Sequence[ScanRequest],
        out_stats: Optional[IOStats] = None,
    ) -> List[ev.EventBatch]:
        """Batched scan (paper: 'optimized multi-range scan with parallel I/O'):
        plans (dedupe + shard grouping), then executes shards concurrently —
        see ``plan()`` / ``execute_plan()``."""
        return self.execute_plan(self.plan(reqs), out_stats)

    # -- introspection ---------------------------------------------------------
    def fanout(self, reqs: Sequence[ScanRequest]) -> int:
        return len({self.router.route(r.user_id) for r in reqs})

    def stored_bytes(self) -> int:
        return sum(
            len(s.blob)
            for shard in self._shards
            for _, stripes in shard.values()
            for s in stripes
        )

    def stored_events(self, user_id: int, group: str) -> int:
        _, entry = self._locate(user_id, group)
        if entry is None:
            return 0
        return sum(s.n_events for s in entry[1])

    def watermark(self, user_id: int, group: str = "core") -> int:
        """Largest timestamp consolidated into the immutable tier for a user."""
        _, entry = self._locate(user_id, group)
        if entry is None or not entry[1]:
            return -1
        return entry[1][-1].end_ts
