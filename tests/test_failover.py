"""Replicated store tier with health-aware failover (DESIGN.md §12).

Covers the robustness PR's acceptance spine:
  * seeded deterministic ``Backoff`` — golden values, bounds, decorrelation
    by token, and the no-jitter exponential ladder;
  * ``CircuitBreaker`` state machine — closed -> open -> probe half-open ->
    close/reopen, driven by a fake clock (no sleeps);
  * replica placement — ``PlacementMap.replicas_of`` anti-affinity, r-way
    bulk-load fan-out, lease fan-in across node death (nothing leaks);
  * the failover executor — pinned scans resolve on survivors, completed
    sibling node groups are retained on a group failure (only the failed
    group re-issues), hedged reads beat an injected-slow primary, and a
    fully-degraded chain raises the *retryable* ``NodeUnavailable``;
  * ``recover()`` — missed bulk loads replay in order, orphaned lease
    releases settle, and reads are byte-identical after the node returns.
"""
import itertools
import threading

import numpy as np
import pytest

from repro.core import events as ev
from repro.core.backoff import Backoff
from repro.storage.failover import (
    CLOSED,
    HALF_OPEN,
    OPEN,
    CircuitBreaker,
    LatencyTracker,
)
from repro.storage.immutable_store import GenerationUnavailable, ScanRequest
from repro.storage.sharded_store import NodeUnavailable, ShardedUIHStore
from repro.storage.sharding import PlacementMap

from test_sharded_store import SCHEMA, _load_skewed, _views_equal


def _store(r=2, **kw):
    kw.setdefault("breaker_reset_s", 0.01)
    return ShardedUIHStore(SCHEMA, n_shards=8, n_nodes=4,
                           replication_factor=r, **kw)


def _user_on(store, node, generation=-1):
    return next(u for u in range(64)
                if store._node_of(u, generation) == node)


# ---------------------------------------------------------------------------
# Backoff (shared helper: store failover + DPP heal)
# ---------------------------------------------------------------------------

def test_backoff_golden_values():
    """Pinned literals: the jitter hash is part of the reproducibility
    contract — chaos timing must be bitwise stable across runs AND releases,
    so a change to the mixing shows up here, deliberately."""
    b = Backoff(base_s=0.01, multiplier=2.0, max_s=0.08, jitter=0.5, seed=7)
    got = [b.delay(a, token=3) for a in range(5)]
    want = [0.009980539724, 0.019210703597, 0.03406879638,
            0.049405271203, 0.044282890921]
    assert got == pytest.approx(want, rel=1e-9, abs=1e-12)
    assert [b.delay(a, token=4) for a in range(3)] == pytest.approx(
        [0.007009669459, 0.017038642906, 0.030318774881],
        rel=1e-9, abs=1e-12)


def test_backoff_deterministic_and_bounded():
    b = Backoff(base_s=0.004, multiplier=2.0, max_s=0.1, jitter=0.5, seed=11)
    again = Backoff(base_s=0.004, multiplier=2.0, max_s=0.1, jitter=0.5,
                    seed=11)
    other_seed = Backoff(base_s=0.004, multiplier=2.0, max_s=0.1, jitter=0.5,
                         seed=12)
    for attempt, token in itertools.product(range(8), range(4)):
        d = b.delay(attempt, token)
        assert d == again.delay(attempt, token)      # pure function
        raw = min(0.004 * 2.0 ** attempt, 0.1)
        assert raw * 0.5 <= d <= raw                 # decrease-only jitter
    # a different seed decorrelates (not a constant offset artifact)
    assert any(b.delay(a, 0) != other_seed.delay(a, 0) for a in range(8))
    # no-jitter ladder is the exact capped exponential
    nb = Backoff(base_s=0.01, multiplier=2.0, max_s=0.08, jitter=0.0)
    assert [nb.delay(a) for a in range(5)] == [0.01, 0.02, 0.04, 0.08, 0.08]


def test_backoff_validation():
    with pytest.raises(ValueError):
        Backoff(base_s=-1.0)
    with pytest.raises(ValueError):
        Backoff(multiplier=0.5)
    with pytest.raises(ValueError):
        Backoff(jitter=1.5)


# ---------------------------------------------------------------------------
# CircuitBreaker state machine (fake clock, no sleeps)
# ---------------------------------------------------------------------------

def test_circuit_breaker_state_machine():
    now = [0.0]
    br = CircuitBreaker(threshold=3, reset_s=1.0, clock=lambda: now[0])
    assert br.state == CLOSED
    assert not br.record_failure()
    assert not br.record_failure()
    assert br.state == CLOSED          # below threshold: still admitting
    assert br.allow()
    assert br.record_failure()         # 3rd consecutive failure opens
    assert br.state == OPEN and br.opens == 1
    assert not br.allow()              # open sheds instantly
    now[0] = 2.0                       # past reset_s
    assert br.allow()                  # -> half-open, ONE probe admitted
    assert br.state == HALF_OPEN
    assert not br.allow()              # second concurrent probe is shed
    assert br.record_failure()         # probe failed: reopen (counted)
    assert br.state == OPEN and br.opens == 2
    now[0] = 4.0
    assert br.allow()
    br.record_success()                # probe succeeded: close + reset count
    assert br.state == CLOSED
    assert not br.record_failure()     # consecutive count restarted
    br.reset()
    assert br.state == CLOSED


def test_circuit_breaker_success_resets_consecutive_count():
    br = CircuitBreaker(threshold=2, reset_s=1.0, clock=lambda: 0.0)
    br.record_failure()
    br.record_success()
    assert not br.record_failure()     # 1 consecutive, not 2
    assert br.state == CLOSED


def test_latency_tracker_cold_then_quantile():
    tr = LatencyTracker(window=32, min_samples=4)
    for s in (0.01, 0.02):
        tr.record(s)
    assert tr.quantile(0.9) is None    # cold: hedging must stay off
    for s in (0.03, 0.04):
        tr.record(s)
    assert tr.quantile(0.0) == 0.01
    assert tr.quantile(0.99) == 0.04
    assert tr.observed_at_least(0.03) == 2


# ---------------------------------------------------------------------------
# replica placement + replicated bulk load
# ---------------------------------------------------------------------------

def test_replicas_of_anti_affinity():
    pm = PlacementMap(4, 8, {5: 2}, replication_factor=3)
    for u in range(40):
        chain = pm.replicas_of(u)
        assert len(chain) == 3
        assert len(set(chain)) == 3            # all distinct nodes
        assert chain[0] == pm.node_of(u)       # primary heads the chain
        assert chain == tuple((chain[0] + k) % 4 for k in range(3))
    assert pm.replicas_of(5)[0] == 2           # override places the primary
    # r=1 degenerates to the primary alone
    assert PlacementMap(4, 8, {}).replicas_of(9) == \
        (PlacementMap(4, 8, {}).node_of(9),)


def test_bulk_load_installs_on_every_replica():
    solo = _store(r=1)
    repl = _store(r=2)
    _load_skewed(solo, generation=0)
    _load_skewed(repl, generation=0)
    # r=2 stores every stripe twice — and on the chain's nodes exactly
    assert repl.stored_bytes() == 2 * solo.stored_bytes()
    pm = repl.live_placement()
    for u in (3, 5, 11):
        chain = pm.replicas_of(u)
        for nid in chain:
            assert repl.nodes[nid].stored_events(u, "core") > 0
        for nid in set(range(4)) - set(chain):
            assert repl.nodes[nid].stored_events(u, "core") == 0
    solo.close()
    repl.close()


# ---------------------------------------------------------------------------
# failover executor: scans survive node loss
# ---------------------------------------------------------------------------

def test_scan_fails_over_to_replica_byte_identical():
    store = _store(r=2)
    _load_skewed(store, generation=0)
    victim = _user_on(store, 2)
    want = store.scan(ScanRequest(victim, "core", 0, 10**9))
    store.set_node_down(2)
    got = store.scan(ScanRequest(victim, "core", 0, 10**9))
    _views_equal(want, got, "failover scan")
    assert store.stats.failovers >= 1
    assert store.stats.degraded_scans == 0
    store.close()


def test_planned_reads_survive_node_loss_and_heal_counters():
    """The whole materialize path (plan -> execute) stays available with a
    node down at r=2, and after enough failures the breaker opens so later
    reads skip the dead primary without paying a failure per call."""
    store = _store(r=2, breaker_threshold=2)
    _load_skewed(store, generation=0)
    reqs = [ScanRequest(u, "core", 0, 10**9) for u in range(16)]
    want = store.multi_range_scan(reqs)
    store.set_node_down(1)
    got = store.multi_range_scan(reqs)
    for i, (a, b) in enumerate(zip(want, got)):
        _views_equal(a, b, f"req {i}")
    s = store.stats
    assert s.failovers >= 1
    # keep reading: the second pass trips the consecutive-failure breaker
    store.multi_range_scan(reqs)
    ns = store.node_stats()
    assert ns.down[1] and ns.breaker[1] in (OPEN, HALF_OPEN)
    assert store.stats.breaker_opens >= 1
    store.close()


def test_pinned_scan_fails_over_to_surviving_retainer():
    """A pinned generation must be served by whichever replica still holds
    the bytes — GenerationUnavailable on one replica consults the next
    instead of surfacing remediation while a survivor retains the data."""
    store = _store(r=2)
    _load_skewed(store, generation=0)
    lease = store.acquire_lease()
    victim = _user_on(store, 0, generation=0)
    want = store.scan(ScanRequest(victim, "core", 0, 10**9, generation=0))
    _load_skewed(store, generation=1)      # flip; gen 0 lease-retained
    store.set_node_down(0)
    got = store.scan(ScanRequest(victim, "core", 0, 10**9, generation=0))
    _views_equal(want, got, "pinned failover")
    assert store.stats.failovers >= 1
    lease.release()
    store.close()


def test_all_replicas_down_raises_retryable_and_recovers():
    """Degraded mode: every replica of a group down -> NodeUnavailable (the
    RETRYABLE class — the DPP self-healing loop owns the wait), never a
    silent drop or a KeyError remediation; byte-identical after recovery."""
    store = _store(r=2, max_group_retries=1,
                   backoff=Backoff(base_s=0.0, jitter=0.0))
    _load_skewed(store, generation=0)
    victim = _user_on(store, 1)
    want = store.scan(ScanRequest(victim, "core", 0, 10**9))
    store.set_node_down(1)
    store.set_node_down(2)                 # 1's replica successor
    with pytest.raises(NodeUnavailable) as ei:
        store.scan(ScanRequest(victim, "core", 0, 10**9))
    assert not isinstance(ei.value, KeyError)
    assert store.stats.degraded_scans == 1
    store.set_node_down(1, down=False)
    store.set_node_down(2, down=False)
    got = store.scan(ScanRequest(victim, "core", 0, 10**9))
    _views_equal(want, got, "post-recovery scan")
    store.close()


def test_partial_reissue_retains_completed_siblings():
    """Satellite 6 regression: one node group failing transiently must NOT
    re-run its completed siblings — the failed group re-issues alone
    (``partial_reissues``), results stay correct, and sibling node IOStats
    are not double-counted."""
    store = _store(r=1, backoff=Backoff(base_s=0.0, jitter=0.0))
    _load_skewed(store, generation=0)
    users = [_user_on(store, n) for n in range(4)]
    reqs = [ScanRequest(u, "core", 0, 10**9) for u in users]
    want = [store.nodes[store._node_of(u)].scan(
        ScanRequest(u, "core", 0, 10**9)) for u in users]
    baseline = {n: store.nodes[n].stats.requests for n in range(4)}

    flaky = store._node_of(users[2])
    inner = store.nodes[flaky].multi_range_scan
    fails = [1]

    def flaky_scan(rs, stats=None):
        if fails[0]:
            fails[0] -= 1
            raise NodeUnavailable(f"injected transient on node {flaky}")
        return inner(rs, stats)

    store.nodes[flaky].multi_range_scan = flaky_scan
    out = store.multi_range_scan(reqs)
    for i, (a, b) in enumerate(zip(want, out)):
        _views_equal(a, b, f"req {i}")
    s = store.stats
    assert s.partial_reissues == 1
    assert s.degraded_scans == 0
    # every node group ran EXACTLY once: siblings were never re-issued, and
    # the flaky group's failed attempt died before reaching the node, so its
    # single physical request is the successful re-issue (no double counting)
    for n in range(4):
        ran = store.nodes[n].stats.requests - baseline[n]
        assert ran == 1, (n, ran)
    store.close()


def test_breakers_open_then_probe_heals_after_recovery():
    """After the outage ends, the open breaker's half-open probe readmits
    the primary — reads return home without an administrative reset."""
    store = _store(r=2, breaker_threshold=1, breaker_reset_s=0.0,
                   max_group_retries=0)
    _load_skewed(store, generation=0)
    victim = _user_on(store, 3)
    req = ScanRequest(victim, "core", 0, 10**9)
    store._down[3] = True                  # raw flag: recovery via probe only
    store.scan(req)                        # trips breaker, serves via replica
    assert store.node_stats().breaker[3] == OPEN
    store._down[3] = False
    base = store.nodes[3].stats.requests
    for _ in range(4):
        store.scan(req)                    # reset_s=0: probe fires right away
    assert store.node_stats().breaker[3] == CLOSED
    assert store.nodes[3].stats.requests > base   # primary serving again
    store.close()


# ---------------------------------------------------------------------------
# hedged reads
# ---------------------------------------------------------------------------

def test_hedged_read_beats_slow_primary():
    store = _store(r=2, hedge_quantile=0.5)
    _load_skewed(store, generation=0)
    victim = _user_on(store, 0)
    req = ScanRequest(victim, "core", 0, 10**9)
    want = store.scan(req)
    for _ in range(20):                    # warm the latency tracker
        store.scan(req)
    assert store.stats.hedged_reads == 0   # healthy tier: no hedges fired
    store.set_node_slow(0, 400.0)
    got = store.scan(req)
    _views_equal(want, got, "hedged scan")
    s = store.stats
    assert s.hedged_reads >= 1
    assert s.hedge_wins >= 1
    assert s.failovers == 0                # hedge is not a failover
    store.close()


def test_hedging_off_below_min_samples():
    store = _store(r=2, hedge_quantile=0.5)
    _load_skewed(store, generation=0)
    victim = _user_on(store, 0)
    store.set_node_slow(0, 50.0)
    store.scan(ScanRequest(victim, "core", 0, 10**9))   # cold tracker
    assert store.stats.hedged_reads == 0
    store.close()


# ---------------------------------------------------------------------------
# lease fan-in + recover() re-replication
# ---------------------------------------------------------------------------

def test_lease_fanin_parks_orphan_on_dead_node_and_recovers():
    """A node dying while leased leaks nothing: release fans in across the
    survivors, the dead node's release parks as an orphan
    (``lease_recoveries``), and recover() settles it so the node's retained
    copy GCs exactly like the survivors'."""
    store = _store(r=2)
    _load_skewed(store, generation=0)
    lease = store.acquire_lease()
    _load_skewed(store, generation=1)      # gen 0 now lease-retained
    store.set_node_down(2)
    lease.release()
    assert store.leased_generations() == {}            # logical refs drained
    assert store.lease_stats.lease_recoveries == 1
    for nid, node in enumerate(store.nodes):
        if nid == 2:
            assert node.has_generation(0)  # orphan: retained until recover
        else:
            assert not node.has_generation(0)
    store.recover(2)
    assert not store.nodes[2].has_generation(0)        # orphan settled
    assert store.retained_generations() == []          # nothing lease-held
    assert store.has_generation(1)                     # live gen intact
    store.close()


def test_recover_replays_missed_loads_in_order():
    store = _store(r=2)
    _load_skewed(store, generation=0)
    store.set_node_down(1)
    _load_skewed(store, generation=1, torso_n=40)      # node 1 misses this
    assert store.node_stats().pending_replays[1] == 1
    assert store.nodes[1].generation == 0
    victim = _user_on(store, 1)
    want = store.scan(ScanRequest(victim, "core", 0, 10**9))  # via replica
    replayed = store.recover(1)
    assert replayed == 1
    assert store.rereplications == 1
    assert store.rereplicated_bytes > 0
    assert store.nodes[1].generation == 1
    got = store.nodes[1].scan(ScanRequest(victim, "core", 0, 10**9))
    _views_equal(want, got, "replayed load")
    assert store.node_stats().pending_replays[1] == 0
    store.close()


def test_acquire_lease_skips_down_node_and_all_down_is_retryable():
    store = _store(r=2)
    _load_skewed(store, generation=0)
    store.set_node_down(0)
    with store.acquire_lease() as lease:
        assert lease.generation == 0       # survivors pin consistently
    for nid in range(1, 4):
        store.set_node_down(nid)
    with pytest.raises(NodeUnavailable):
        store.acquire_lease()
    assert store.leased_generations() == {}
    store.close()


# ---------------------------------------------------------------------------
# DPP heal + backoff: the pool survives a retry whose delay is still elapsing
# ---------------------------------------------------------------------------

def test_dpp_pool_retry_backoff_drains_without_deadlock():
    """Regression: while a healed item's backoff delay elapses, the retry is
    in neither the queue nor the retry deque — the pool (workers AND the
    ordered placer) must stay open for it instead of draining out and
    wedging join() forever."""
    from repro.dpp.elastic import DPPWorkerPool

    placed = []
    crashed = []

    class _Worker:
        def __init__(self):
            self.stats = type("S", (), {"busy_time_s": 0.0,
                                        "total_time_s": 0.0})()

        def process(self, item):
            if item[0] == "poison" and not crashed:
                crashed.append(True)
                raise IOError("injected mid-item crash")
            return list(item)

    class _Client:
        def put(self, out):
            placed.append(out)

        def close(self):
            pass

    pool = DPPWorkerPool(
        _Worker, _Client(), n_workers=2, max_item_retries=2, ordered=True,
        retry_backoff=Backoff(base_s=0.05, multiplier=1.0, jitter=0.0))
    # MORE items than the reorder-buffer admission cap (8 for 2 workers):
    # while the poison item's backoff elapses, the other workers run ahead
    # and block in admission on far seqs — the retry must still find a thread
    items = [["a"], ["poison"]] + [[f"x{i}"] for i in range(14)]
    pool.start(items)
    t = threading.Thread(target=pool.join, daemon=True)
    t.start()
    t.join(timeout=30.0)
    assert not t.is_alive(), "pool.join() wedged on the in-flight retry"
    assert placed == items                 # ordered, byte-identical, complete
    assert pool.items_requeued == 1
    assert pool.worker_restarts == 1
