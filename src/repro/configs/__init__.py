"""Architecture registry: ``--arch <id>`` resolution for launch/dryrun/tests."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchSpec

_MODULES = {
    "qwen3-8b": "repro.configs.qwen3_8b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "granite-8b": "repro.configs.granite_8b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "meshgraphnet": "repro.configs.meshgraphnet",
    "two-tower-retrieval": "repro.configs.two_tower_retrieval",
    "dcn-v2": "repro.configs.dcn_v2",
    "dien": "repro.configs.dien",
    "bert4rec": "repro.configs.bert4rec",
    "dlrm-uih": "repro.configs.dlrm_uih",
}

# the 10 assigned archs (dlrm-uih is the paper's own, listed separately)
ASSIGNED: List[str] = [a for a in _MODULES if a != "dlrm-uih"]


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).spec()


def list_archs(include_paper_own: bool = True) -> List[str]:
    return list(_MODULES) if include_paper_own else list(ASSIGNED)
