"""Cell builders: (arch × shape × mesh) -> step function + input specs +
shardings. ``input_specs()`` returns ShapeDtypeStructs only — the dry-run
never allocates full-size arrays.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchSpec
from repro.launch import shardings as SH
from repro.launch.mesh import all_axes_of, data_axes_of
from repro.models import gnn as G
from repro.models import recsys as R
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig, AdamWState, adamw_init, make_train_step

S = jax.ShapeDtypeStruct


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    step_fn: Callable          # positional args
    args_spec: Tuple[Any, ...] # ShapeDtypeStruct pytrees (positional)
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    model_flops: float         # 6ND-style useful flops for this step
    meta: Dict[str, Any]


def _div(b: int, axes_size: int) -> bool:
    return b % axes_size == 0 and b >= axes_size


def _batch_axes(mesh, b: int):
    da = data_axes_of(mesh)
    size = int(np.prod([mesh.shape[a] for a in da]))
    return (da if _div(b, size) else None), da


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def _lm_params_spec(cfg, mesh, serving: bool = False, moe_2d: bool = False):
    pshape = jax.eval_shape(lambda: T.init(jax.random.PRNGKey(0), cfg))
    if serving:  # inference holds bf16 weights (no fp32 master needed)
        pshape = jax.tree.map(
            lambda l: S(l.shape, jnp.bfloat16)
            if jnp.issubdtype(l.dtype, jnp.floating) else l, pshape)
    return pshape, SH.lm_param_specs(pshape, mesh, moe_2d=moe_2d)


def build_lm_cell(spec: ArchSpec, shape_name: str, mesh,
                  use_full: bool = True, cfg_override=None) -> Cell:
    cfg = cfg_override or (spec.full if use_full else spec.smoke)
    shp = spec.shapes[shape_name]
    b, sl = shp["batch"], shp["seq_len"]
    if not use_full:  # smoke: shrink shapes
        b, sl = max(2, b // 128), min(sl, 64)
    da = data_axes_of(mesh)
    b_axes, _ = _batch_axes(mesh, b)
    moe_data_axes = b_axes if (cfg.moe is not None and shp["kind"] == "decode") \
        else (da if cfg.moe is not None else da)
    if cfg.moe is not None and shp["kind"] == "decode" and b_axes is None:
        moe_data_axes = ()
    kind = shp["kind"]
    # decode: fully-resident 2D expert sharding (no per-step FSDP all-gather)
    if cfg.moe is not None and kind == "decode":
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, ep_mode="2d"))
    pshape, pspec = _lm_params_spec(
        cfg, mesh, serving=(kind != "train"),
        moe_2d=(cfg.moe is not None and cfg.moe.ep_mode == "2d"))
    n_params = cfg.active_param_count()

    if kind == "train":
        opt_cfg = AdamWConfig()
        oshape = jax.eval_shape(lambda: adamw_init(
            jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), pshape)))
        ospec = SH.opt_specs(pspec, pshape, mesh)
        loss = lambda p, batch: T.loss_fn(p, batch["tokens"], batch["targets"],
                                          cfg, mesh=mesh, data_axes=da)
        step = make_train_step(loss, opt_cfg)
        batch_spec = {
            "tokens": S((b, sl), jnp.int32),
            "targets": S((b, sl), jnp.int32),
        }
        batch_sh = {
            "tokens": P(b_axes, None),
            "targets": P(b_axes, None),
        }
        return Cell(
            spec.arch_id, shape_name, kind, step,
            (pshape, oshape, batch_spec),
            (pspec, ospec, batch_sh),
            (pspec, ospec, P()),
            model_flops=6.0 * n_params * b * sl,
            meta={"tokens": b * sl, "cfg": cfg},
        )

    if kind == "prefill":
        fn = lambda p, batch: T.prefill(p, batch["tokens"], cfg, mesh=mesh,
                                        data_axes=da)
        batch_spec = {"tokens": S((b, sl), jnp.int32)}
        batch_sh = {"tokens": P(b_axes, None)}
        cache_sh = _kv_cache_spec(cfg, mesh, b, sl, stacked=True)[1]
        return Cell(
            spec.arch_id, shape_name, kind, fn,
            (pshape, batch_spec), (pspec, batch_sh),
            (P(b_axes, "model"), cache_sh),
            model_flops=2.0 * n_params * b * sl,
            meta={"tokens": b * sl, "cfg": cfg},
        )

    # decode
    cache_shape, cache_sh = _kv_cache_spec(cfg, mesh, b, sl, stacked=True)
    fn = lambda p, cache, batch: T.decode_step(
        p, cache, batch["token"], batch["position"], cfg, mesh=mesh,
        data_axes=moe_data_axes)
    batch_spec = {
        "token": S((b,), jnp.int32),
        "position": S((b,), jnp.int32),
    }
    batch_sh = {"token": P(b_axes), "position": P(b_axes)}
    return Cell(
        spec.arch_id, shape_name, kind, fn,
        (pshape, cache_shape, batch_spec),
        (pspec, cache_sh, batch_sh),
        (P(b_axes, "model"), cache_sh),
        model_flops=2.0 * n_params * b,   # + attention KV term reported in meta
        meta={"tokens": b, "kv_len": sl, "cfg": cfg},
    )


def _kv_cache_spec(cfg, mesh, b: int, sl: int, stacked: bool):
    da = data_axes_of(mesh)
    size_da = int(np.prod([mesh.shape[a] for a in da]))
    if _div(b, size_da):
        b_ax, s_ax = da, "model"
    else:
        # batch too small: flash-decoding style sequence sharding over all axes
        b_ax, s_ax = None, tuple(all_axes_of(mesh))
    dt = cfg.compute_dtype
    if cfg.attention == "mla":
        shape = {
            "c_kv": S((cfg.n_layers, b, sl, cfg.kv_lora_rank), dt),
            "k_pe": S((cfg.n_layers, b, sl, cfg.qk_rope_dim), dt),
        }
        sh = {
            "c_kv": P(None, b_ax, s_ax, None),
            "k_pe": P(None, b_ax, s_ax, None),
        }
    else:
        shape = {
            "k": S((cfg.n_layers, b, sl, cfg.n_kv_heads, cfg.head_dim), dt),
            "v": S((cfg.n_layers, b, sl, cfg.n_kv_heads, cfg.head_dim), dt),
        }
        sh = {
            "k": P(None, b_ax, s_ax, None, None),
            "v": P(None, b_ax, s_ax, None, None),
        }
    return shape, sh


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def build_gnn_cell(spec: ArchSpec, shape_name: str, mesh,
                   use_full: bool = True, cfg_override=None) -> Cell:
    base_cfg = cfg_override or (spec.full if use_full else spec.smoke)
    shp = spec.shapes[shape_name]
    n, e, d_feat = shp["n_nodes"], shp["n_edges"], shp["d_feat"]
    if not use_full:
        n, e, d_feat = min(n, 64), min(e, 256), min(d_feat, 8)
    cfg = dataclasses.replace(base_cfg, d_node_in=d_feat)
    # pad edges to a multiple of the full device count for clean sharding
    ndev = int(np.prod(list(mesh.shape.values())))
    e_pad = int(np.ceil(e / ndev) * ndev)
    axes = tuple(all_axes_of(mesh))

    pshape = jax.eval_shape(lambda: G.init(jax.random.PRNGKey(0), cfg))
    pspec = SH.gnn_param_specs(pshape, mesh)
    opt_cfg = AdamWConfig()
    oshape = jax.eval_shape(lambda: adamw_init(
        jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), pshape)))
    ospec = SH.opt_specs(pspec, pshape, mesh)

    def loss(p, batch):
        return G.loss_fn(p, batch["node_feats"], batch["edge_feats"],
                         batch["senders"], batch["receivers"],
                         batch["targets"], cfg, edge_mask=batch["edge_mask"])

    step = make_train_step(loss, opt_cfg)
    batch_spec = {
        "node_feats": S((n, d_feat), jnp.float32),
        "edge_feats": S((e_pad, cfg.d_edge_in), jnp.float32),
        "senders": S((e_pad,), jnp.int32),
        "receivers": S((e_pad,), jnp.int32),
        "edge_mask": S((e_pad,), jnp.bool_),
        "targets": S((n, cfg.d_out), jnp.float32),
    }
    batch_sh = {
        "node_feats": P(None, None),          # replicated (vertex-cut)
        "edge_feats": P(axes, None),
        "senders": P(axes),
        "receivers": P(axes),
        "edge_mask": P(axes),
        "targets": P(None, None),
    }
    # flops: per MP layer ~ edges * (3h->h MLP) + nodes * (2h->h MLP)
    h = cfg.d_hidden
    mp = cfg.n_layers * (e * (3 * h * h + h * h) + n * (2 * h * h + h * h)) * 2
    enc = (n * d_feat * h + e * cfg.d_edge_in * h + n * h * cfg.d_out) * 2
    return Cell(
        spec.arch_id, shape_name, "train", step,
        (pshape, oshape, batch_spec),
        (pspec, ospec, batch_sh),
        (pspec, ospec, P()),
        model_flops=3.0 * (mp + enc),        # fwd + bwd ~ 3x fwd
        meta={"n_nodes": n, "n_edges": e, "cfg": cfg},
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def _recsys_batch(arch_id: str, cfg, b: int, mesh, with_label: bool):
    """(spec, shardings) for one batch of each recsys tenant's features."""
    b_axes, _ = _batch_axes(mesh, b)
    bp = lambda *rest: P(b_axes, *rest)
    if arch_id == "two-tower-retrieval":
        spec = {
            "user_id": S((b,), jnp.int32),
            "uih_item_id": S((b, cfg.uih_len), jnp.int32),
            "uih_mask": S((b, cfg.uih_len), jnp.bool_),
            "cand_item_id": S((b,), jnp.int32),
        }
        sh = {
            "user_id": bp(), "uih_item_id": bp(None), "uih_mask": bp(None),
            "cand_item_id": bp(),
        }
        if with_label:
            spec["log_q"] = S((b,), jnp.float32)
            sh["log_q"] = bp()
    elif arch_id == "dcn-v2":
        spec = {
            "dense": S((b, cfg.n_dense), jnp.float32),
            "sparse_ids": S((b, cfg.n_sparse), jnp.int32),
        }
        sh = {"dense": bp(None), "sparse_ids": bp(None)}
    elif arch_id == "dien":
        spec = {
            "uih_item_id": S((b, cfg.seq_len), jnp.int32),
            "uih_category": S((b, cfg.seq_len), jnp.int32),
            "uih_mask": S((b, cfg.seq_len), jnp.bool_),
            "cand_item_id": S((b,), jnp.int32),
            "cand_category": S((b,), jnp.int32),
        }
        sh = {
            "uih_item_id": bp(None), "uih_category": bp(None),
            "uih_mask": bp(None), "cand_item_id": bp(), "cand_category": bp(),
        }
    elif arch_id == "bert4rec":
        spec = {
            "uih_item_id": S((b, cfg.seq_len), jnp.int32),
            "uih_mask": S((b, cfg.seq_len), jnp.bool_),
        }
        sh = {"uih_item_id": bp(None), "uih_mask": bp(None)}
        if with_label:
            spec["mask_pos"] = S((b, cfg.seq_len), jnp.bool_)
            sh["mask_pos"] = bp(None)
            spec["neg_ids"] = S((1024,), jnp.int32)
            sh["neg_ids"] = P(None)
        else:
            spec["cand_item_id"] = S((b,), jnp.int32)
            sh["cand_item_id"] = bp()
    elif arch_id == "dlrm-uih":
        spec = {
            "uih_item_id": S((b, cfg.seq_len), jnp.int32),
            "uih_action_type": S((b, cfg.seq_len), jnp.int32),
            "uih_mask": S((b, cfg.seq_len), jnp.bool_),
            "cand_item_id": S((b,), jnp.int32),
            "sparse_ids": S((b, cfg.n_sparse), jnp.int32),
            "dense": S((b, cfg.n_dense), jnp.float32),
        }
        sh = {
            "uih_item_id": bp(None), "uih_action_type": bp(None),
            "uih_mask": bp(None), "cand_item_id": bp(),
            "sparse_ids": bp(None), "dense": bp(None),
        }
    else:
        raise KeyError(arch_id)
    if with_label and arch_id not in ("two-tower-retrieval", "bert4rec"):
        spec["label"] = S((b,), jnp.float32)
        sh["label"] = bp()
    return spec, sh


_RECSYS_FNS = {
    "two-tower-retrieval": (R.init_two_tower, R.two_tower_loss, None,
                            R.two_tower_score_candidates),
    "dcn-v2": (R.init_dcn_v2, R.dcn_v2_loss, R.dcn_v2_forward,
               R.dcn_v2_score_candidates),
    "dien": (R.init_dien, R.dien_loss, R.dien_forward, None),
    "bert4rec": (R.init_bert4rec, R.bert4rec_loss, R.bert4rec_forward,
                 R.bert4rec_score_candidates),
    "dlrm-uih": (R.init_dlrm_uih, R.dlrm_uih_loss, R.dlrm_uih_forward,
                 R.dlrm_uih_score_candidates),
}


def _two_tower_towers(cfg):
    d = cfg.embed_dim
    user = 2 * d * cfg.tower_mlp[0] + sum(
        cfg.tower_mlp[i] * cfg.tower_mlp[i + 1]
        for i in range(len(cfg.tower_mlp) - 1))
    item = d * cfg.tower_mlp[0] + sum(
        cfg.tower_mlp[i] * cfg.tower_mlp[i + 1]
        for i in range(len(cfg.tower_mlp) - 1))
    return user, item


def _recsys_flops(arch_id: str, cfg, b: int) -> float:
    """Per-step useful forward flops (dense-equivalent), x3 for training."""
    if arch_id == "two-tower-retrieval":
        d = cfg.embed_dim
        user, item = _two_tower_towers(cfg)
        return 2.0 * b * (user + item + cfg.uih_len * d) + 2.0 * b * b * d
    if arch_id == "dcn-v2":
        d = cfg.d_interact
        mlp = d * cfg.mlp[0] + sum(cfg.mlp[i] * cfg.mlp[i + 1]
                                   for i in range(len(cfg.mlp) - 1))
        return 2.0 * b * (cfg.n_cross_layers * d * d + mlp)
    if arch_id == "dien":
        per_step = 2 * (cfg.d_in * 3 * cfg.gru_dim + cfg.gru_dim * 3 * cfg.gru_dim)
        return 2.0 * b * cfg.seq_len * per_step
    if arch_id == "bert4rec":
        d = cfg.embed_dim
        per_tok = 12 * d * d + 2 * cfg.seq_len * d  # attn+ffn+scores
        return 2.0 * b * cfg.seq_len * cfg.n_blocks * per_tok
    if arch_id == "dlrm-uih":
        d = cfg.d_seq
        per_tok = 12 * d * d + 2 * cfg.seq_len * d
        return 2.0 * b * cfg.seq_len * cfg.n_seq_layers * per_tok
    raise KeyError(arch_id)


def build_recsys_cell(spec: ArchSpec, shape_name: str, mesh,
                      use_full: bool = True, cfg_override=None) -> Cell:
    cfg = cfg_override or (spec.full if use_full else spec.smoke)
    shp = spec.shapes[shape_name]
    b = shp["batch"]
    n_cand = shp.get("n_candidates", 0)
    if not use_full:
        b = max(2, min(b, 8))
        n_cand = min(n_cand, 64)
    init_fn, loss_fn, fwd_fn, score_fn = _RECSYS_FNS[spec.arch_id]
    kind = shp["kind"]
    # train/serve cells use the shard_map row-sharded embedding path;
    # retrieval cells keep the GSPMD path (candidate ids shard over all axes)
    if kind in ("train", "serve") and use_full:
        cfg = dataclasses.replace(cfg, mesh=mesh,
                                  data_axes=data_axes_of(mesh))
    pshape = jax.eval_shape(lambda: init_fn(jax.random.PRNGKey(0), cfg))
    if kind != "train":  # serving holds bf16 weights
        pshape = jax.tree.map(
            lambda l: S(l.shape, jnp.bfloat16)
            if jnp.issubdtype(l.dtype, jnp.floating) else l, pshape)
    pspec = SH.recsys_param_specs(pshape, mesh)
    axes = tuple(all_axes_of(mesh))
    fwd_flops = _recsys_flops(spec.arch_id, cfg, b)

    if kind == "train":
        opt_cfg = AdamWConfig()
        oshape = jax.eval_shape(lambda: adamw_init(
            jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), pshape)))
        ospec = SH.opt_specs(pspec, pshape, mesh)
        batch_spec, batch_sh = _recsys_batch(spec.arch_id, cfg, b, mesh, True)
        step = make_train_step(lambda p, batch: loss_fn(p, batch, cfg),
                               AdamWConfig())
        return Cell(
            spec.arch_id, shape_name, kind, step,
            (pshape, oshape, batch_spec),
            (pspec, ospec, batch_sh),
            (pspec, ospec, P()),
            model_flops=3.0 * fwd_flops,
            meta={"batch": b, "cfg": cfg},
        )

    if kind == "serve":
        batch_spec, batch_sh = _recsys_batch(spec.arch_id, cfg, b, mesh, False)
        if spec.arch_id == "two-tower-retrieval":
            fn = lambda p, batch: R.two_tower_user(
                p, batch["user_id"], batch["uih_item_id"], batch["uih_mask"], cfg)
            b_axes, _ = _batch_axes(mesh, b)
            out_sh = P(b_axes, None)
            user, _ = _two_tower_towers(cfg)
            fwd_flops = 2.0 * b * (user + cfg.uih_len * cfg.embed_dim)
        else:
            fn = lambda p, batch: fwd_fn(p, batch, cfg)
            b_axes, _ = _batch_axes(mesh, b)
            out_sh = P(b_axes)
        return Cell(
            spec.arch_id, shape_name, kind, fn,
            (pshape, batch_spec), (pspec, batch_sh), out_sh,
            model_flops=fwd_flops,
            meta={"batch": b, "cfg": cfg},
        )

    # retrieval_cand
    batch_spec, batch_sh = _recsys_batch(spec.arch_id, cfg, 1, mesh, False)
    ndev = int(np.prod(list(mesh.shape.values())))
    n_cand = int(np.ceil(n_cand / ndev) * ndev)   # pad to shard boundary
    cand_spec = S((n_cand,), jnp.int32)
    cand_sh = P(axes)
    if spec.arch_id == "dien":
        fn = lambda p, batch, cand, cand_cat: R.dien_score_candidates(
            p, batch, cand, cand_cat, cfg)
        args = (pshape, batch_spec, cand_spec, S((n_cand,), jnp.int32))
        in_sh = (pspec, batch_sh, cand_sh, cand_sh)
    else:
        fn = lambda p, batch, cand: score_fn(p, batch, cand, cfg)
        args = (pshape, batch_spec, cand_spec)
        in_sh = (pspec, batch_sh, cand_sh)
    return Cell(
        spec.arch_id, shape_name, kind, fn,
        args, in_sh, P(axes) if spec.arch_id in ("dcn-v2", "dien", "dlrm-uih")
        else P(None, axes),
        model_flops=_retrieval_flops(spec.arch_id, cfg, n_cand),
        meta={"n_candidates": n_cand, "cfg": cfg},
    )


def _retrieval_flops(arch_id: str, cfg, n: int) -> float:
    """Shared encoders run ONCE; only the per-candidate tail scales with N."""
    if arch_id == "two-tower-retrieval":
        user, item = _two_tower_towers(cfg)
        return 2.0 * (user + cfg.uih_len * cfg.embed_dim) \
            + 2.0 * n * (item + cfg.embed_dim)
    if arch_id == "dcn-v2":
        return _recsys_flops(arch_id, cfg, n)    # full forward per candidate
    if arch_id == "dien":
        h, s = cfg.gru_dim, cfg.seq_len
        gru1_once = 2.0 * s * (cfg.d_in * 3 * h + h * 3 * h)
        per_cand = 2.0 * s * (h * 3 * h + h * 3 * h) \
            + 2.0 * s * h + 2.0 * (h + 2 * cfg.d_in) * cfg.mlp[0]
        return gru1_once + n * per_cand
    if arch_id == "bert4rec":
        d = cfg.embed_dim
        enc_once = 2.0 * cfg.seq_len * cfg.n_blocks * (12 * d * d
                                                       + 4 * cfg.seq_len * d)
        return enc_once + 2.0 * n * d
    if arch_id == "dlrm-uih":
        d = cfg.d_seq
        enc_once = 2.0 * cfg.seq_len * cfg.n_seq_layers * (12 * d * d
                                                           + 4 * cfg.seq_len * d)
        f = 3 + cfg.n_sparse
        pairs = f * (f - 1) // 2
        per_cand = (2.0 * cfg.seq_len * d                 # target-aware pooling
                    + 2.0 * 3 * d * cfg.embed_dim         # projections
                    + 2.0 * f * f * cfg.embed_dim         # interactions
                    + 2.0 * ((pairs + cfg.embed_dim) * cfg.top_mlp[0]
                             + cfg.top_mlp[0] * cfg.top_mlp[1]))
        return enc_once + n * per_cand
    raise KeyError(arch_id)


# ---------------------------------------------------------------------------
# Device feed: host data plane -> sharded device batches
#
# DEPRECATED SHIMS. The declarative read path (repro.data) replaced both of
# these: describe the feed as a DatasetSpec and call
# ``repro.data.open_feed(spec, sim, cell=cell, mesh=mesh, prep_fn=...)``.
# The shims keep old call sites working — same arguments, same behavior —
# but now return the uniform ``repro.data.Feed`` protocol (which iterates,
# ``get``s, and records train steps exactly like the DevicePrefetcher they
# used to return) and emit a DeprecationWarning.
# ---------------------------------------------------------------------------

def make_device_feed(cell: Cell, source, mesh=None, depth: int = 2,
                     prep_fn=None, stats=None, recycle_host: bool = False):
    """DEPRECATED: use ``repro.data.open_feed`` (this is a thin shim).

    Double-buffered device feed for a cell's input batches: wraps a
    host-batch source (a ``RebatchingClient``, or any iterable of host batch
    dicts) in a ``DevicePrefetcher`` whose ``device_put`` honors the cell's
    batch shardings, returned behind the uniform ``Feed`` protocol.
    """
    import warnings

    warnings.warn(
        "launch.steps.make_device_feed is deprecated; build a "
        "repro.data.DatasetSpec and call repro.data.open_feed(...) instead",
        DeprecationWarning, stacklevel=2)
    return _shim_feed(cell, source, mesh, depth, prep_fn, stats, recycle_host)


def make_streaming_feed(cell: Cell, session, mesh=None, depth: int = 2,
                        prep_fn=None, recycle_host: bool = False):
    """DEPRECATED: use ``repro.data.open_feed`` with a ``StreamSource`` spec
    (this is a thin shim).

    Wraps a ``repro.streaming.StreamingSession`` in the cell-sharded device
    prefetcher behind the uniform ``Feed`` protocol: H2D overlaps the step
    exactly as in batch mode while the session settles event→gradient
    freshness and releases generation leases. ``session.start()`` is implicit
    on first pull."""
    import warnings

    warnings.warn(
        "launch.steps.make_streaming_feed is deprecated; build a "
        "repro.data.DatasetSpec(source=StreamSource(...)) and call "
        "repro.data.open_feed(...) instead",
        DeprecationWarning, stacklevel=2)
    return _shim_feed(cell, session, mesh, depth, prep_fn, None, recycle_host)


def _shim_feed(cell, source, mesh, depth, prep_fn, stats, recycle_host):
    from repro.data.compile import cell_input_sharding
    from repro.data.feed import Feed
    from repro.dpp.prefetch import DevicePrefetcher
    from repro.streaming.session import StreamingSession

    sharding = cell_input_sharding(cell, mesh)
    pf = DevicePrefetcher(source, depth=depth, sharding=sharding,
                          prep_fn=prep_fn, stats=stats,
                          recycle_host=recycle_host)
    session = source if isinstance(source, StreamingSession) else None
    client = source if (session is None and hasattr(source, "recycle")
                       and hasattr(source, "get_full_batch")) else None
    return Feed(pf, client=client, session=session, prefetcher=pf,
                prep_fn=prep_fn)


def build_cell(spec: ArchSpec, shape_name: str, mesh, use_full=True,
               cfg_override=None) -> Cell:
    if spec.family == "lm":
        return build_lm_cell(spec, shape_name, mesh, use_full, cfg_override)
    if spec.family == "gnn":
        return build_gnn_cell(spec, shape_name, mesh, use_full, cfg_override)
    if spec.family == "recsys":
        return build_recsys_cell(spec, shape_name, mesh, use_full, cfg_override)
    raise KeyError(spec.family)
