"""DLRM-UIH — the paper's own flagship tenant: DLRM interaction + causal
transformer encoder over an ultra-long UIH sequence (the Fig.4 scaling knob).
Fed end-to-end by the versioned-late-materialization data plane."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import DLRMUIHConfig

FULL = DLRMUIHConfig(
    name="dlrm-uih", seq_len=2048, d_seq=128, n_seq_layers=2, n_heads=4,
    n_dense=13, n_sparse=4, embed_dim=64, item_vocab=10_000_384,
    field_vocab=1_000_448,
)

SMOKE = DLRMUIHConfig(
    name="dlrm-uih-smoke", seq_len=32, d_seq=16, n_seq_layers=2, n_heads=2,
    n_dense=4, n_sparse=2, embed_dim=8, item_vocab=1_000, field_vocab=100,
    compute_dtype=jnp.float32,
)


def spec() -> ArchSpec:
    return ArchSpec(
        "dlrm-uih", "recsys", FULL, SMOKE, RECSYS_SHAPES,
        notes="paper's own architecture (not from the assigned pool)",
    )
