"""Qwen3-4B [hf:Qwen/Qwen3-4B]: 36L d2560 32H GQA(kv=8) d_ff 9728 v151936,
qk-norm."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="qwen3-4b", n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=9728, vocab=151_936, head_dim=128, qk_norm=True, rope_theta=1e6,
)

SMOKE = TransformerConfig(
    name="qwen3-4b-smoke", n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=173, head_dim=16, qk_norm=True, rope_theta=1e6,
    compute_dtype=jnp.float32, q_chunk=16, loss_chunk=16,
)


def spec() -> ArchSpec:
    return ArchSpec("qwen3-4b", "lm", FULL, SMOKE, LM_SHAPES)
