"""The per-run telemetry facade: one object carrying the registry, event
log and span tracker through the whole data plane (DESIGN.md §13).

Construct one ``Telemetry`` per run, hand it to ``DatasetSpec(telemetry=...)``
and/or ``TrainerConfig(telemetry=...)``, and ``open_feed`` threads it through
the store, pool, client, session, prefetcher and feed.  Everything is
optional and additive: with ``telemetry=None`` (the default) every hook in
the data plane degrades to a single attribute-is-None check.

``write_run_dir(path)`` dumps the run's artifacts:

    metrics.json    registry snapshot (series, histogram buckets, p50/95/99)
    metrics.prom    Prometheus text exposition of the same registry
    events.jsonl    control-plane event timeline (one record per line)
    spans.jsonl     completed sampled batch spans (one batch per line)
    summary.json    span lifecycle counts + critical-path attribution

``python -m repro.obs.report <run_dir>`` renders them for humans.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, Optional, Sequence

from repro.obs.events import EventLog
from repro.obs.registry import DEFAULT_BUCKETS, MetricsRegistry, publish_dataclass
from repro.obs.spans import SpanTracker

DEFAULT_SAMPLE_EVERY = 8


class Telemetry:
    """Registry + event log + span tracker for one run."""

    def __init__(self, *, sample_every: int = DEFAULT_SAMPLE_EVERY,
                 span_capacity: int = 2048, event_capacity: int = 4096) -> None:
        self.registry = MetricsRegistry()
        self.events = EventLog(capacity=event_capacity)
        self.spans = SpanTracker(sample_every=sample_every,
                                 capacity=span_capacity,
                                 registry=self.registry)

    def publish_stats(self, obj: Any, prefix: str,
                      gauge_fields: Sequence[str] = (),
                      **labels: Any) -> None:
        """Publish a legacy ``*Stats`` dataclass snapshot into the registry
        (see :func:`repro.obs.registry.publish_dataclass` for the naming
        rule)."""
        publish_dataclass(self.registry, obj, prefix=prefix,
                          labels=labels, gauge_fields=gauge_fields)

    def critical_path(self, *, starved_host_s: float = 0.0,
                      starved_h2d_s: float = 0.0,
                      starved_time_s: float = 0.0) -> Dict[str, Any]:
        return self.spans.critical_path(starved_host_s=starved_host_s,
                                        starved_h2d_s=starved_h2d_s,
                                        starved_time_s=starved_time_s)

    def summary(self) -> Dict[str, Any]:
        starved = {
            "starved_time_s": _counter_value(
                self.registry, "repro_client_starved_time_s_total"),
            "starved_host_s": _counter_value(
                self.registry, "repro_client_starved_host_s_total"),
            "starved_h2d_s": _counter_value(
                self.registry, "repro_client_starved_h2d_s_total"),
        }
        return {
            "spans": self.spans.lifecycle_counts(),
            "events": self.events.counts(),
            "critical_path": self.spans.critical_path(
                starved_host_s=starved["starved_host_s"],
                starved_h2d_s=starved["starved_h2d_s"],
                starved_time_s=starved["starved_time_s"]),
        }

    def write_run_dir(self, path) -> Path:
        out = Path(path)
        out.mkdir(parents=True, exist_ok=True)
        (out / "metrics.json").write_text(
            json.dumps(self.registry.to_dict(), indent=1, default=str))
        (out / "metrics.prom").write_text(self.registry.prometheus_text())
        self.events.write_jsonl(out / "events.jsonl")
        self.spans.write_jsonl(out / "spans.jsonl")
        (out / "summary.json").write_text(
            json.dumps(self.summary(), indent=1, default=str))
        return out


def _counter_value(registry: MetricsRegistry, name: str) -> float:
    """Sum of one counter family across all label sets (0.0 if absent)."""
    for fam in registry.families():
        if fam.name == name:
            return sum(child.value for _, child in fam.series())
    return 0.0
