"""Pure-jnp oracle for EmbeddingBag (gather + masked segment reduction)."""
import jax
import jax.numpy as jnp


def embedding_bag(table: jax.Array, ids: jax.Array, mask: jax.Array,
                  combiner: str = "sum") -> jax.Array:
    """table (V, D); ids (B, L) int32; mask (B, L). Returns (B, D)."""
    emb = table[ids] * mask[..., None].astype(table.dtype)
    s = jnp.sum(emb, axis=1)
    if combiner == "sum":
        return s
    if combiner == "mean":
        denom = jnp.maximum(mask.sum(axis=1, keepdims=True), 1).astype(table.dtype)
        return s / denom
    raise ValueError(combiner)
