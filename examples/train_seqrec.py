"""End-to-end training driver: the complete stack, one process.

  synthetic traffic -> mutable/immutable tiers -> VLM snapshots -> warehouse
  -> DPP workers (projection pushdown + rebatching) -> DLRM-UIH trainer
  (AdamW, grad accumulation, crash-safe checkpointing with auto-resume).

Run:  PYTHONPATH=src python examples/train_seqrec.py [--steps 200] [--resume]
The model is the paper's flagship tenant (DLRM + UIH transformer encoder) at a
CPU-sized config; the same driver drives pod-scale meshes via --arch configs.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core.projection import TenantProjection
from repro.core.simulation import ProductionSim, SimConfig
from repro.dpp.client import RebatchingClient
from repro.dpp.featurize import FeatureSpec
from repro.dpp.worker import DPPWorker
from repro.models import recsys as R
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import Trainer, TrainerConfig

SEQ_LEN = 48
BATCH = 32


def build_pipeline(seed: int = 0):
    sim = ProductionSim(SimConfig(
        stream=ev.StreamConfig(n_users=32, n_items=4_000, days=7,
                               events_per_user_day_mean=40.0, seed=seed),
        stripe_len=32, requests_per_user_day=6, seed=seed,
    ))
    sim.run_days(6, capture_reference=False)
    tenant = TenantProjection(
        "dlrm-uih", seq_len=SEQ_LEN,
        feature_groups=("core", "sideinfo"),
        traits_per_group={"core": ("timestamp", "item_id", "action_type"),
                          "sideinfo": ("category",)})
    spec = FeatureSpec(seq_len=SEQ_LEN,
                       uih_traits=("item_id", "action_type", "category"),
                       candidate_fields=("item_id",), label_fields=("click",))
    mat = sim.materializer(validate_checksum=False)
    mat.window_cache_size = 256
    worker = DPPWorker(mat, tenant, spec, sim.schema)
    return sim, worker


def batches(sim, worker, cfg, seed=0):
    """Infinite shuffled epochs through the warehouse via the DPP worker."""
    client = RebatchingClient(BATCH, buffer_batches=4, shuffle_seed=seed)
    rng = np.random.default_rng(seed)
    while True:
        order = rng.permutation(len(sim.examples))
        for lo in range(0, len(order) - 8 + 1, 8):
            base = [sim.examples[i] for i in order[lo : lo + 8]]
            client.put(worker.process(base))     # base batches of 8 -> 32
            full = client.get_full_batch(timeout=0)
            if full is not None:
                yield prep(full, cfg)


def prep(b, cfg):
    return {
        "uih_item_id": jnp.asarray(b["uih_item_id"] % cfg.item_vocab, jnp.int32),
        "uih_action_type": jnp.asarray(b["uih_action_type"] % 16, jnp.int32),
        "uih_mask": jnp.asarray(b["uih_mask"]),
        "cand_item_id": jnp.asarray(b["cand_item_id"] % cfg.item_vocab, jnp.int32),
        "sparse_ids": jnp.asarray(
            np.stack([b["user_id"] % cfg.field_vocab,
                      b["cand_item_id"] % cfg.field_vocab], 1), jnp.int32),
        "dense": jnp.asarray(np.stack([b["uih_mask"].sum(1)] * 4, 1),
                             jnp.float32) / SEQ_LEN,
        "label": jnp.asarray(b["label_click"], jnp.float32),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_seqrec_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = R.DLRMUIHConfig(
        name="seqrec", seq_len=SEQ_LEN, d_seq=32, n_seq_layers=2, n_heads=4,
        n_dense=4, n_sparse=2, embed_dim=16, item_vocab=4_096,
        field_vocab=4_096, compute_dtype=jnp.float32, remat=False)
    params = R.init_dlrm_uih(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"DLRM-UIH: {n_params/1e6:.2f}M params, seq_len={SEQ_LEN}")

    sim, worker = build_pipeline()
    trainer = Trainer(
        lambda p, b: R.dlrm_uih_loss(p, b, cfg), params,
        TrainerConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=20,
                                      total_steps=args.steps),
                      ckpt_dir=args.ckpt_dir, ckpt_every=50, grad_accum=2,
                      log_every=20))
    if args.resume and trainer.try_resume():
        print(f"resumed from step {trainer.step}")

    t0 = time.perf_counter()
    trainer.fit(batches(sim, worker, cfg), max_steps=args.steps)
    dt = time.perf_counter() - t0
    first = np.mean([h["loss"] for h in trainer.history[:10]])
    last = np.mean([h["loss"] for h in trainer.history[-10:]])
    print(f"\ntrained {trainer.step} steps in {dt:.1f}s "
          f"({trainer.step / dt:.1f} steps/s)")
    print(f"loss {first:.4f} -> {last:.4f}")
    print(f"immutable store served {worker.materializer.immutable.stats.requests}"
          f" scans, {worker.materializer.immutable.stats.bytes_scanned/1e6:.1f} MB")


if __name__ == "__main__":
    main()
