"""Kernel + codec micro-benchmarks.

CPU wall-times for Pallas interpret mode are NOT TPU predictions — the derived
columns report the host-side codec/decode rates (the quantities that matter for
DPP sizing) and kernel-vs-oracle agreement."""
from __future__ import annotations

from typing import List

import jax.numpy as jnp
import numpy as np

from benchmarks.common import BenchResult, timeit
from repro.core import events as ev
from repro.kernels.delta_decode import ops as dd_ops
from repro.kernels.delta_decode import ref as dd_ref
from repro.kernels.embedding_bag import ops as eb_ops
from repro.kernels.jagged import ops as jg_ops
from repro.storage import columnar


def run(quick: bool = False) -> List[BenchResult]:
    out: List[BenchResult] = []
    rng = np.random.default_rng(0)
    schema = ev.default_schema()

    # columnar codec encode/decode rate (host-side DPP hot path)
    n = 2_000 if quick else 50_000
    ts = np.sort(rng.integers(0, 1 << 40, size=n)).astype(np.int64)
    batch = {
        "timestamp": ts,
        "item_id": rng.integers(0, 1 << 22, size=n).astype(np.int64),
        "action_type": rng.integers(0, 8, size=n).astype(np.int32),
        "like": (rng.random(n) < 0.05).astype(np.int8),
    }
    blob = columnar.encode_stripe(batch, schema)
    t_enc = timeit(lambda: columnar.encode_stripe(batch, schema))
    t_dec = timeit(lambda: columnar.decode_stripe(blob, schema))
    t_sel = timeit(lambda: columnar.decode_stripe(blob, schema,
                                                  ("timestamp", "item_id")))
    raw = sum(v.nbytes for v in batch.values())
    out.append(BenchResult("codec/encode", t_enc,
                           {"MB_per_s": round(raw / t_enc, 1),
                            "compression_ratio": round(raw / len(blob), 2)}))
    out.append(BenchResult("codec/decode_full", t_dec,
                           {"MB_per_s": round(raw / t_dec, 1)}))
    out.append(BenchResult("codec/decode_projected", t_sel,
                           {"speedup_vs_full": round(t_dec / t_sel, 2)}))

    # delta-decode kernel (interpret) vs oracle
    deltas = rng.integers(0, 1 << 16, size=(4, 64) if quick else (8, 512)
                          ).astype(np.int32)
    bases = rng.integers(0, 1 << 20, size=deltas.shape[0]).astype(np.int32)
    dj, bj = jnp.asarray(deltas), jnp.asarray(bases)
    got = dd_ops.delta_decode(dj, bj)
    want = dd_ref.delta_decode(dj, bj)
    t_k = timeit(lambda: dd_ops.delta_decode(dj, bj).block_until_ready())
    out.append(BenchResult("kernel/delta_decode", t_k,
                           {"exact_match": bool(np.array_equal(got, want)),
                            "elements": deltas.size}))

    # jagged->padded kernel (interpret)
    rows, ml = (8, 16) if quick else (64, 64)
    lens = rng.integers(0, int(1.5 * ml), size=rows)
    offsets = np.zeros(rows + 1, np.int32); np.cumsum(lens, out=offsets[1:])
    values = rng.standard_normal((int(offsets[-1]), 128)).astype(np.float32)
    vj, oj = jnp.asarray(values), jnp.asarray(offsets)
    t_j = timeit(lambda: jg_ops.jagged_to_padded(vj, oj, ml).block_until_ready())
    out.append(BenchResult("kernel/jagged_to_padded", t_j,
                           {"rows": rows, "max_len": ml, "d": 128}))

    # embedding bag kernel (interpret)
    bags, bag_len = (4, 8) if quick else (32, 20)
    table = jnp.asarray(rng.standard_normal((4096, 128)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 4096, (bags, bag_len)), jnp.int32)
    mask = jnp.ones((bags, bag_len), bool)
    t_e = timeit(lambda: eb_ops.embedding_bag(table, ids, mask)
                 .block_until_ready())
    out.append(BenchResult("kernel/embedding_bag", t_e,
                           {"bags": bags, "bag_len": bag_len, "d": 128}))
    return out


if __name__ == "__main__":
    for r in run():
        print(r.csv())
