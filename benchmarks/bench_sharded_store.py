"""Disaggregated immutable tier (DESIGN.md §11): FlexShard-style placement.

Two claims, on a heavy-tailed (Pareto-ish) user population:
  * length-aware placement cuts the MAX-node load ratio vs pure hashing —
    ultra-long users stop hot-spotting one node (FlexShard, 2301.02959);
  * batched-scan throughput scales with node count {1, 2, 4} under a
    remote-I/O latency model (node groups execute concurrently, so wall time
    per batch is the max over nodes, not the sum).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import BenchResult
from repro.core import events as ev
from repro.storage.compaction import CompactionConfig, CompactionPipeline
from repro.storage.immutable_store import ScanRequest
from repro.storage.sharded_store import ShardedUIHStore

SCHEMA = ev.default_schema()
N_SHARDS = 8

# remote-storage latency model, charged per shard of each node round-trip
# (heavy enough that remote I/O dominates host-side decode, as it does for a
# genuinely disaggregated tier)
LATENCY = (lambda seeks, nbytes, fanout:
           1e-2 * seeks + nbytes / 3e7 + 5e-4 * max(fanout - 1, 0))


def _population(n_users: int, mean_events: int, seed: int = 7
                ) -> Dict[int, ev.EventBatch]:
    """Heavy-tailed event counts: a Pareto tail over a uniform torso — the
    top ~5% of users carry the majority of bytes, like production UIH."""
    rng = np.random.default_rng(seed)
    counts = (mean_events * (1.0 + rng.pareto(1.1, n_users) * 3.0)).astype(int)
    events = {}
    for uid in range(n_users):
        # cap the tail at 25x the mean: ultra-long, but no single user so
        # pathological that it alone serializes every configuration
        n = int(min(counts[uid], mean_events * 25))
        per_user = np.random.default_rng(seed + uid + 1)
        batch = {}
        for name in SCHEMA.trait_names:
            dt = SCHEMA.spec(name).dtype
            batch[name] = per_user.integers(0, 1_000, n).astype(dt)
        batch["timestamp"] = np.sort(
            per_user.integers(0, 900_000, n)).astype(np.int64)
        events[uid] = batch
    return events


def _build(events: Dict[int, ev.EventBatch], n_nodes: int,
           policy: str, n_shards: int = N_SHARDS) -> ShardedUIHStore:
    store = ShardedUIHStore(SCHEMA, n_shards=n_shards, n_nodes=n_nodes,
                            placement_policy=policy)
    pipe = CompactionPipeline(SCHEMA, CompactionConfig(stripe_len=64))
    pipe.run(lambda uid, lo, hi: ev.time_slice(events[uid], lo, hi),
             list(events), 1_000_000, store, generation=0)
    return store


def _scan_all(store: ShardedUIHStore, users: List[int],
              batch_size: int) -> float:
    """Full-window batched scans over every user; returns wall seconds."""
    t0 = time.perf_counter()
    for lo in range(0, len(users), batch_size):
        reqs = [ScanRequest(u, "core", 0, 10**9)
                for u in users[lo:lo + batch_size]]
        store.multi_range_scan(reqs)
    return time.perf_counter() - t0


def run(quick: bool = False) -> List[BenchResult]:
    n_users, mean_events, batch = (32, 40, 8) if quick else (256, 120, 32)
    events = _population(n_users, mean_events)
    users = list(events)

    # -- skew: hash vs length-aware on 4 nodes -------------------------------
    results: List[BenchResult] = []
    skews = {}
    for policy in ("hash", "length_aware"):
        store = _build(events, 4, policy)
        _scan_all(store, users, batch)
        ns = store.node_stats()
        skews[policy] = ns
        store.close()
    results.append(BenchResult(
        "sharded_store/max_node_load", 0.0,
        {"hash_max_mean": round(skews["hash"].max_mean_load_ratio, 3),
         "length_aware_max_mean":
             round(skews["length_aware"].max_mean_load_ratio, 3),
         "hash_stored_max_mean":
             round(skews["hash"].max_mean_stored_ratio, 3),
         "length_aware_stored_max_mean":
             round(skews["length_aware"].max_mean_stored_ratio, 3),
         "hash_node_bytes": skews["hash"].scan_load,
         "length_aware_node_bytes": skews["length_aware"].scan_load},
    ))

    # -- throughput scaling over node counts {1, 2, 4} -----------------------
    # scale-out semantics: each node brings its own fixed local parallelism
    # (2 shards/node), so 4 nodes really is 4x the 1-node I/O capacity
    walls = {}
    for n_nodes in (1, 2, 4):
        store = _build(events, n_nodes, "length_aware",
                       n_shards=2 * n_nodes)
        store.latency_model = LATENCY
        wall = _scan_all(store, users, batch)
        store.latency_model = None
        walls[n_nodes] = wall
        store.close()
    thr = {n: len(users) / w for n, w in walls.items()}
    results.append(BenchResult(
        "sharded_store/scan_throughput_scaling",
        walls[4] / len(users) * 1e6,
        {"users_per_s_1node": round(thr[1], 1),
         "users_per_s_2node": round(thr[2], 1),
         "users_per_s_4node": round(thr[4], 1),
         "speedup_2node": round(thr[2] / thr[1], 2),
         "speedup_4node": round(thr[4] / thr[1], 2)},
    ))
    return results


if __name__ == "__main__":
    for r in run():
        print(r.csv())
