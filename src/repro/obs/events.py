"""Control-plane event log: bounded ring buffer + JSONL sink (DESIGN.md §13).

Counters say *how many* breaker opens happened; the event log says *when*,
*on which node*, and *in what order relative to everything else* — the
timeline that turns "hedge_wins=3, worker_restarts=2" into a story a human
can debug from.  Producers call ``emit(kind, **fields)`` from any thread;
each record gets a process-monotonic sequence number, a ``time.monotonic()``
timestamp (ordering; never goes backwards) and a ``time.time()`` wall stamp
(cross-process correlation).  The ring holds the most recent ``capacity``
events; ``to_jsonl_lines`` / ``write_jsonl`` dump it for the report CLI.

Event kinds emitted by the wired data plane (one line each in the run's
``events.jsonl``): ``generation_flip``, ``lease_acquire``, ``lease_release``,
``breaker_open``, ``breaker_half_open``, ``breaker_close``, ``failover``,
``hedge_win``, ``degraded_scan``, ``partial_reissue``, ``node_down``,
``node_recover``, ``worker_crash``, ``item_requeued``, ``item_abandoned``,
``worker_restart``, ``backfill_flip``, ``stream_reconnect``,
``checkpoint_save``, ``checkpoint_resume``.
"""
from __future__ import annotations

import collections
import json
import threading
import time
from typing import Any, Deque, Dict, List, Optional


class Event:
    __slots__ = ("seq", "t_mono", "t_wall", "kind", "fields")

    def __init__(self, seq: int, t_mono: float, t_wall: float, kind: str,
                 fields: Dict[str, Any]) -> None:
        self.seq = seq
        self.t_mono = t_mono
        self.t_wall = t_wall
        self.kind = kind
        self.fields = fields

    def to_dict(self) -> Dict[str, Any]:
        return {"seq": self.seq, "t_mono": self.t_mono, "t_wall": self.t_wall,
                "kind": self.kind, **self.fields}

    def __repr__(self) -> str:
        return f"Event({self.seq}, {self.kind}, {self.fields})"


class EventLog:
    """Thread-safe bounded ring of control-plane events."""

    def __init__(self, capacity: int = 4096) -> None:
        self.capacity = capacity
        self._ring: Deque[Event] = collections.deque(maxlen=capacity)
        self._seq = 0
        self._emitted = 0
        self._lock = threading.Lock()

    def emit(self, kind: str, **fields: Any) -> Event:
        t_mono = time.monotonic()
        t_wall = time.time()
        with self._lock:
            self._seq += 1
            self._emitted += 1
            ev = Event(self._seq, t_mono, t_wall, kind, fields)
            self._ring.append(ev)
        return ev

    @property
    def emitted(self) -> int:
        """Lifetime emit count (>= len(snapshot()) once the ring wraps)."""
        return self._emitted

    def snapshot(self) -> List[Event]:
        with self._lock:
            return list(self._ring)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ev in self.snapshot():
            out[ev.kind] = out.get(ev.kind, 0) + 1
        return out

    def to_jsonl_lines(self) -> List[str]:
        return [json.dumps(ev.to_dict(), default=str)
                for ev in self.snapshot()]

    def write_jsonl(self, path) -> None:
        with open(path, "w") as f:
            for line in self.to_jsonl_lines():
                f.write(line + "\n")
