"""Atomic, keep-k checkpoint manager (numpy container format, no orbax dep).

Fault-tolerance contract:
  * writes go to ``<dir>/tmp.step_N`` and are atomically renamed to
    ``<dir>/step_N`` — a crash mid-save never corrupts the latest checkpoint;
  * ``latest_step``/``restore`` skip unfinished tmp dirs, so restart always
    resumes from the newest COMPLETE checkpoint;
  * ``keep`` newest checkpoints are retained, older ones garbage-collected
    only after a successful save (never delete-then-write); the newest
    complete checkpoint is never GC'd;
  * a content checksum guards against partial/bit-rotted files; a ``restore``
    asked for the *latest* checkpoint falls back to the previous complete one
    when the newest fails validation (an explicitly requested step never
    falls back — the caller named it);
  * ``feed_state`` (a ``repro.data.Feed.checkpoint()`` dict) is saved as a
    sidecar INSIDE the checkpoint dir, so data-plane cursor and model state
    publish atomically together — the exactly-once resume contract (§10)
    needs them to name the same step.
"""
from __future__ import annotations

import json
import shutil
import zlib
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- save -------------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[dict] = None,
             feed_state: Optional[dict] = None) -> Path:
        arrays, treedef = _flatten(state)
        tmp = self.dir / f"tmp.step_{step:09d}"
        final = self.dir / f"step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        # npz can't represent ml_dtypes (bfloat16, fp8): store raw bytes + dtype
        dtypes = {k: a.dtype.name for k, a in arrays.items()}
        storable = {
            k: (a.view(np.uint8) if a.dtype.name not in np.sctypeDict else a)
            for k, a in arrays.items()
        }
        np.savez(tmp / "arrays.npz", **storable)
        crc = 0
        for name in sorted(arrays):
            crc = zlib.crc32(arrays[name].tobytes(), crc)
        meta = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(arrays),
            "dtypes": dtypes,
            "crc32": crc & 0xFFFFFFFF,
            "extra": extra or {},
        }
        (tmp / "meta.json").write_text(json.dumps(meta))
        if feed_state is not None:
            # sidecar written BEFORE the atomic rename: model state and feed
            # cursor publish together or not at all
            (tmp / "feed.json").write_text(json.dumps(feed_state))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                      # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and \
                    (p / "meta.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def feed_state(self, step: Optional[int] = None) -> Optional[dict]:
        """The data-plane cursor saved atomically with ``step`` (default:
        latest), or ``None`` when that checkpoint carried no feed sidecar.
        Pass it to ``repro.data.open_feed(resume_from=...)``."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        p = self.dir / f"step_{step:09d}" / "feed.json"
        if not p.exists():
            return None
        return json.loads(p.read_text())

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, int, dict]:
        """Restore into the structure of ``template``. ``shardings`` (optional
        pytree of NamedSharding) re-places leaves onto a mesh — possibly a
        DIFFERENT mesh than the one that saved (elastic reshard).

        With ``step=None`` (resume-from-latest), a checkpoint that fails
        validation (bit rot, torn write that survived the rename) falls back
        to the next older COMPLETE checkpoint — crashing the restart on the
        newest file's corruption would make one bad disk block fatal. The
        newest failure is re-raised only when every checkpoint is bad. An
        EXPLICIT ``step`` never falls back."""
        if step is not None:
            return self._restore_step(template, step, shardings)
        steps = self.all_steps()
        assert steps, "no checkpoint found"
        first_err: Optional[Exception] = None
        for s in reversed(steps):
            try:
                return self._restore_step(template, s, shardings)
            except Exception as e:
                if first_err is None:
                    first_err = e
        raise first_err  # type: ignore[misc]

    def _restore_step(self, template: Any, step: int,
                      shardings: Any = None) -> Tuple[Any, int, dict]:
        path = self.dir / f"step_{step:09d}"
        meta = json.loads((path / "meta.json").read_text())
        with np.load(path / "arrays.npz") as z:
            arrays = {k: z[k] for k in z.files}
        for k, dt in meta.get("dtypes", {}).items():
            if arrays[k].dtype.name != dt:
                arrays[k] = arrays[k].view(np.dtype(dt))
        crc = 0
        for name in sorted(arrays):
            crc = zlib.crc32(arrays[name].tobytes(), crc)
        if (crc & 0xFFFFFFFF) != meta["crc32"]:
            raise IOError(f"checkpoint {path} failed checksum validation")
        leaves, treedef = jax.tree_util.tree_flatten(template)
        assert len(leaves) == meta["n_leaves"], "tree structure changed"
        restored = [arrays[f"leaf_{i}"] for i in range(len(leaves))]
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_flatten(
                shardings, is_leaf=lambda x: hasattr(x, "spec"))[0]
            restored = [jax.device_put(a, s)
                        for a, s in zip(restored, sh_leaves)]
        else:
            restored = [jax.numpy.asarray(a) for a in restored]
        state = jax.tree_util.tree_unflatten(treedef, restored)
        return state, step, meta["extra"]
