"""Online streaming training driver: the "O" in O2O, end to end.

  warehouse backfill (catch-up) -> flip to live stream (exactly-once watermark)
  -> micro-batched DPP materialization with generation-pinned windows
  -> slot-based rebatching -> device prefetch -> DLRM-UIH trainer

while LIVE traffic keeps arriving AND daily compaction publishes new
immutable generations underneath — the generation-lease protocol keeps every
materialized window byte-exact to what the ranking service saw.

The whole pipeline is ONE declarative spec: the same ``DatasetSpec`` ->
``open_feed`` -> ``Feed`` path the batch driver uses, with
``source=StreamSource(...)`` and ``generations="pinned"`` — batch vs
streaming is a spec field, not a second code path.

Run:  PYTHONPATH=src python examples/train_streaming.py [--live-days 2]
"""
import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core.projection import TenantProjection
from repro.core.simulation import ProductionSim, SimConfig
from repro.data import DatasetSpec, StreamSource, open_feed
from repro.dpp.featurize import FeatureSpec
from repro.models import recsys as R
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import Trainer, TrainerConfig

SEQ_LEN = 48
BATCH = 32


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--history-days", type=int, default=2,
                    help="warehouse days replayed by the catch-up backfill")
    ap.add_argument("--live-days", type=int, default=2,
                    help="days of live traffic consumed after the flip")
    ap.add_argument("--max-wall-s", type=float, default=120.0)
    args = ap.parse_args()

    sim = ProductionSim(SimConfig(
        stream=ev.StreamConfig(
            n_users=24, n_items=4_000,
            days=args.history_days + args.live_days + 1,
            events_per_user_day_mean=40.0, seed=0),
        stripe_len=32, requests_per_user_day=6, seed=0,
        pin_generations=True))
    # history phase: the warehouse head is sealed before the coordinator forms
    sim.run_days(args.history_days, capture_reference=False)
    print(f"history: {len(sim.examples)} examples across "
          f"{len(sim.warehouse.hours())} warehouse hours, "
          f"immutable generation {sim.immutable.generation}")

    tenant = TenantProjection(
        "dlrm-uih", seq_len=SEQ_LEN,
        feature_groups=("core", "sideinfo"),
        traits_per_group={"core": ("timestamp", "item_id", "action_type"),
                          "sideinfo": ("category",)})
    spec = DatasetSpec(
        tenant=tenant,
        source=StreamSource(backfill=True, micro_batch_examples=8,
                            micro_batch_delay_s=0.05),
        consistency="audit",        # checksum-validate every full window (O2O)
        generations="pinned",       # scan the logged (leased) generation
        batch_size=BATCH, prefetch_depth=2, n_workers=2,
        window_cache_size=256,
        features=FeatureSpec(seq_len=SEQ_LEN,
                             uih_traits=("item_id", "action_type", "category"),
                             candidate_fields=("item_id",),
                             label_fields=("click",)))

    def producer():
        try:
            for day in range(args.history_days,
                             args.history_days + args.live_days):
                sim.run_day(day, capture_reference=False)
        finally:
            sim.stream.close()

    prod = threading.Thread(target=producer, daemon=True)
    prod.start()

    cfg = R.DLRMUIHConfig(
        name="seqrec-online", seq_len=SEQ_LEN, d_seq=32, n_seq_layers=2,
        n_heads=4, n_dense=4, n_sparse=2, embed_dim=16, item_vocab=4_096,
        field_vocab=4_096, compute_dtype=jnp.float32, remat=False)
    params = R.init_dlrm_uih(jax.random.PRNGKey(0), cfg)

    def prep(b):
        return {
            "uih_item_id": (b["uih_item_id"] % cfg.item_vocab).astype(np.int32),
            "uih_action_type": (b["uih_action_type"] % 16).astype(np.int32),
            "uih_mask": b["uih_mask"],
            "cand_item_id": (b["cand_item_id"] % cfg.item_vocab).astype(np.int32),
            "sparse_ids": np.stack([b["user_id"] % cfg.field_vocab,
                                    b["cand_item_id"] % cfg.field_vocab],
                                   1).astype(np.int32),
            "dense": np.stack([b["uih_mask"].sum(1)] * 4, 1).astype(np.float32)
            / SEQ_LEN,
            "label": b["label_click"].astype(np.float32),
        }

    trainer = Trainer(
        lambda p, b: R.dlrm_uih_loss(p, b, cfg), params,
        TrainerConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=20,
                                      total_steps=10_000),
                      grad_accum=2, log_every=20,
                      max_wall_s=args.max_wall_s))

    feed = open_feed(spec, sim, prep_fn=prep)
    t0 = time.perf_counter()
    trainer.fit(feed)   # runs until the stream drains (or max_wall_s)
    dt = time.perf_counter() - t0
    # close() (not join()): if the wall bound fired first, the remaining
    # stream must be drained untrained so blocked workers can shut down
    feed.close()
    prod.join()

    session = feed.session
    bf = session.backfill_stats
    st = feed.stats()
    fr, cs = st.freshness, st.client
    ls = sim.immutable.lease_stats
    total = len(sim.examples)
    print(f"\ntrained {trainer.step} steps in {dt:.1f}s "
          f"({trainer.step / dt:.1f} steps/s)")
    print(f"catch-up handoff: {bf.warehouse_examples} from warehouse "
          f"(watermark={bf.watermark}), {bf.stream_examples} live, "
          f"{bf.duplicates_skipped} stream duplicates skipped "
          f"-> {bf.warehouse_examples + bf.stream_examples}/{total} "
          f"trained exactly once")
    print(f"freshness: event->gradient mean "
          f"{fr.mean_event_to_gradient_s * 1e3:.0f}ms, max "
          f"{fr.event_to_gradient_s_max * 1e3:.0f}ms "
          f"({fr.samples} live rows); stream lag peak "
          f"{session.source.stats.max_lag}")
    print(f"generations: live={sim.immutable.generation}, leases "
          f"{ls.acquired} acquired / {ls.released} released, "
          f"{ls.generations_retained} retained / {ls.generations_gc} GC'd")
    ws = st.workers
    mats = [w.materializer for w in session.pool._workers]
    pinned = sum(m.stats.pinned_windows for m in mats)
    stale = sum(m.stats.stale_reresolved for m in mats)
    fails = sum(m.stats.stale_failures for m in mats)
    print(f"materialization: {ws.examples} examples, {pinned} pinned windows, "
          f"{stale} stale re-resolved, {fails} failures; "
          f"feed starvation {cs.starvation_pct:.1f}%")


if __name__ == "__main__":
    main()
