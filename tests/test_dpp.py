"""DPP layer: featurization, rebatching, pipelined prefetch, elastic scaling,
straggler mitigation, affinity planning."""
import threading
import time

import numpy as np
import pytest

from repro.core import events as ev
from repro.core.projection import TenantProjection
from repro.core.simulation import ProductionSim, SimConfig
from repro.dpp.affinity import plan_affine, plan_arrival_order
from repro.dpp.client import RebatchingClient
from repro.dpp.elastic import (
    DPPWorkerPool,
    ElasticConfig,
    ElasticController,
    StragglerAwarePool,
)
from repro.dpp.featurize import FeatureSpec, featurize, pad_sequences
from repro.dpp.worker import DPPWorker, probe_from_list


@pytest.fixture(scope="module")
def sim():
    cfg = SimConfig(
        stream=ev.StreamConfig(n_users=8, n_items=1_000, days=4,
                               events_per_user_day_mean=40.0, seed=1),
        stripe_len=16,
        requests_per_user_day=4,
        mode="vlm",
        seed=1,
    )
    s = ProductionSim(cfg)
    s.run_days(3, capture_reference=False)
    return s


PROJ = TenantProjection("t", seq_len=64, feature_groups=("core",),
                        traits_per_group={"core": ("timestamp", "item_id")})
SPEC = FeatureSpec(seq_len=64, uih_traits=("item_id", "timestamp"))


def test_pad_sequences_right_aligned():
    seqs = [np.array([1, 2, 3]), np.array([], dtype=np.int64), np.arange(10)]
    out = pad_sequences(seqs, 5)
    np.testing.assert_array_equal(out[0], [0, 0, 1, 2, 3])
    np.testing.assert_array_equal(out[1], [0, 0, 0, 0, 0])
    np.testing.assert_array_equal(out[2], [5, 6, 7, 8, 9])  # truncate keeps recent


def test_worker_base_batch_shapes(sim):
    worker = DPPWorker(sim.materializer(), PROJ, SPEC, sim.schema)
    batch = worker.process(sim.examples[:10])
    assert batch["uih_item_id"].shape == (10, 64)
    assert batch["uih_mask"].shape == (10, 64)
    assert batch["label_click"].shape == (10,)
    assert not np.isnan(batch["label_click"]).any()
    # mask aligns with lens
    np.testing.assert_array_equal(batch["uih_mask"].sum(1), batch["uih_len"])


def test_worker_respects_future_boundary(sim):
    worker = DPPWorker(sim.materializer(), PROJ, SPEC, sim.schema)
    batch = worker.process(sim.examples[:20])
    ts = batch["uih_timestamp"]
    mask = batch["uih_mask"]
    req = batch["request_ts"][:, None]
    assert np.all(ts[mask] <= np.broadcast_to(req, ts.shape)[mask])


def test_rebatching_exact_full_batches(sim):
    client = RebatchingClient(full_batch_size=16, buffer_batches=64)
    worker = DPPWorker(sim.materializer(), PROJ, SPEC, sim.schema)
    for i in range(0, 48, 6):  # base batches of 6 -> full batches of 16
        client.put(worker.process(sim.examples[i : i + 6]))
    client.close()
    sizes = [len(b["uih_len"]) for b in client]
    assert sizes == [16, 16, 16]


def test_rebatching_reshuffles(sim):
    client = RebatchingClient(full_batch_size=16, shuffle_seed=0)
    worker = DPPWorker(sim.materializer(), PROJ, SPEC, sim.schema)
    users_in = [e.user_id for e in sim.examples[:16]]
    client.put(worker.process(sim.examples[:16]))
    client.close()
    full = client.get_full_batch()
    assert sorted(full["user_id"].tolist()) == sorted(users_in)


def test_pipelined_overlaps_and_matches_serial(sim):
    """Pipelining must (a) produce identical batches, (b) be faster when probe
    and lookup latencies are comparable (paper: ~10% improvement)."""
    examples = sim.examples[:32]
    delay = 0.01
    def make_worker():
        mat = sim.materializer(validate_checksum=False)
        mat.immutable.latency_model = lambda seeks, nbytes, fanout: delay
        w = DPPWorker(mat, PROJ, SPEC, sim.schema, probe_latency_s=delay)
        return w

    w1 = make_worker()
    serial = list(w1.run_serial(probe_from_list(examples, 8)))
    t_serial = w1.stats.total_time_s

    w2 = make_worker()
    piped = list(w2.run_pipelined(probe_from_list(examples, 8)))
    t_piped = w2.stats.total_time_s

    assert len(serial) == len(piped) == 4
    for a, b in zip(serial, piped):
        np.testing.assert_array_equal(a["uih_item_id"], b["uih_item_id"])
    assert t_piped < t_serial  # overlap must help with comparable latencies


def test_elastic_controller_scales_on_starvation():
    ctl = ElasticController(ElasticConfig(min_workers=1, max_workers=8))
    w = 2
    w = ctl.decide(w, starvation_pct=10.0, waste_pct=10.0)
    assert w == 3  # starving -> scale up
    w = ctl.decide(w, starvation_pct=0.0, waste_pct=80.0)
    assert w == 2  # wasteful and not starving -> scale down
    w = ctl.decide(w, starvation_pct=0.0, waste_pct=10.0)
    assert w == 2  # steady state


def test_straggler_pool_respeculates():
    slow_once = threading.Event()

    def work(payload):
        if payload == "slow" and not slow_once.is_set():
            slow_once.set()
            time.sleep(0.5)  # straggler
            return "late"
        return "ok"

    pool = StragglerAwarePool(work, n_workers=2, straggler_deadline_s=0.05)
    payloads = {0: "slow", 1: "fast"}
    pool.submit(0, "slow")
    pool.submit(1, "fast")
    out = pool.gather([0, 1], payloads, timeout_s=5.0)
    assert len(out) == 2
    assert pool.stats.speculative_retries >= 1
    pool.shutdown()


def test_pool_survives_worker_exception():
    calls = {"n": 0}
    lock = threading.Lock()

    def flaky(payload):
        with lock:
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("worker crash")
        return payload * 2

    pool = StragglerAwarePool(flaky, n_workers=2, straggler_deadline_s=5.0)
    pool.submit(0, 21)
    out = pool.gather([0], {0: 21}, timeout_s=5.0)
    assert out == [42]
    assert pool.stats.worker_failures == 1
    pool.shutdown()


def test_worker_pool_single_worker_matches_serial(sim):
    """One pool worker over planned items == the serial put loop, batch for
    batch (the pool adds no reordering of its own at concurrency 1)."""
    items = [sim.examples[i : i + 6] for i in range(0, 48, 6)]

    serial = RebatchingClient(16, buffer_batches=64, shuffle_seed=3)
    w = DPPWorker(sim.materializer(validate_checksum=False), PROJ, SPEC,
                  sim.schema)
    for item in items:
        serial.put_jagged(w.process_jagged(item))
    serial.close()
    want = list(serial)

    pooled = RebatchingClient(16, buffer_batches=64, shuffle_seed=3)
    pool = DPPWorkerPool(
        lambda: DPPWorker(sim.materializer(validate_checksum=False), PROJ,
                          SPEC, sim.schema),
        pooled, n_workers=1)
    pool.run(items)
    got = list(pooled)
    assert len(got) == len(want)
    for g, w_ in zip(got, want):
        for k in w_:
            np.testing.assert_array_equal(g[k], w_[k], err_msg=k)
    assert pool.items_done == len(items)
    assert pool.merged_worker_stats().examples == 48


def test_worker_pool_parallel_covers_all_examples(sim):
    items = [sim.examples[i : i + 5] for i in range(0, len(sim.examples), 5)]
    client = RebatchingClient(8, buffer_batches=1024, shuffle_seed=0)
    pool = DPPWorkerPool(
        lambda: DPPWorker(sim.materializer(validate_checksum=False), PROJ,
                          SPEC, sim.schema),
        client, n_workers=4,
        controller=ElasticController(ElasticConfig(min_workers=1,
                                                   max_workers=6)),
        control_interval_s=0.01)
    pool.run(items)
    got_users = []
    for b in client:
        got_users.extend(b["user_id"].tolist())
    assert sorted(got_users) == sorted(e.user_id for e in sim.examples)
    assert pool.merged_worker_stats().examples == len(sim.examples)


def test_worker_pool_propagates_worker_failure(sim):
    class Exploding:
        def __init__(self):
            from repro.dpp.worker import WorkerStats
            self.stats = WorkerStats()

        def process_jagged(self, item):
            raise RuntimeError("worker blew up")

    client = RebatchingClient(8, buffer_batches=64, shuffle_seed=0)
    pool = DPPWorkerPool(Exploding, client, n_workers=2)
    with pytest.raises(RuntimeError):
        pool.run([sim.examples[:4]])


def test_make_device_feed_places_cell_batches(sim):
    """launch.steps.make_device_feed: device batches come back resident and
    shaped per the cell's batch spec."""
    from repro.configs import dlrm_uih as DU
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import build_cell, make_device_feed

    spec = DU.spec()
    mesh = make_test_mesh(1)
    cell = build_cell(spec, "train_batch", mesh, use_full=False)
    bspec = cell.args_spec[-1]
    rng = np.random.default_rng(0)
    host = [{k: np.asarray(rng.integers(0, 2, s.shape)).astype(s.dtype)
             for k, s in bspec.items()} for _ in range(3)]
    feed = make_device_feed(cell, host, mesh=mesh, depth=2)
    out = list(feed)
    assert len(out) == 3
    for db in out:
        for k, s in bspec.items():
            assert db[k].shape == s.shape and db[k].dtype == s.dtype


def test_affinity_plan_reduces_fanout_and_amortizes(sim):
    n_shards = sim.immutable.router.n_shards
    base = 8
    affine = plan_affine(sim.examples, n_shards, base)
    arrival = plan_arrival_order(sim.examples, n_shards, base)
    assert affine.expected_fanout < arrival.expected_fanout
    assert affine.amortizable_pairs > arrival.amortizable_pairs


def test_affinity_amortization_cuts_lookup_bytes(sim):
    """Same-user adjacent examples share the immutable window -> fewer scans."""
    n_shards = sim.immutable.router.n_shards
    affine = plan_affine(sim.examples, n_shards, 8)
    arrival = plan_arrival_order(sim.examples, n_shards, 8)

    def run(plan):
        mat = sim.materializer(validate_checksum=False)
        before = sim.immutable.stats.snapshot()
        for item in plan.items:
            mat.materialize_batch(item, PROJ)
        return sim.immutable.stats.delta(before)

    d_affine = run(affine)
    d_arrival = run(arrival)
    assert d_affine.bytes_scanned < d_arrival.bytes_scanned
    assert d_affine.requests < d_arrival.requests
