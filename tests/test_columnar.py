"""Trait-aware columnar codec: roundtrip + selective decoding + density wins."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to a fixed-examples sweep (see the shim)
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import events as ev
from repro.storage import columnar


SCHEMA = ev.default_schema()


def _random_batch(n: int, seed: int = 0) -> ev.EventBatch:
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.integers(0, 10**9, size=n)).astype(np.int64)
    return {
        "timestamp": ts,
        "item_id": rng.integers(0, 50_000, size=n).astype(np.int64),
        "action_type": rng.integers(0, 8, size=n).astype(np.int32),
        "surface": rng.integers(0, 4, size=n).astype(np.int32),
        "watch_time_ms": rng.integers(0, 100_000, size=n).astype(np.int32),
        "like": (rng.random(n) < 0.05).astype(np.int8),
        "comment": (rng.random(n) < 0.01).astype(np.int8),
        "share": (rng.random(n) < 0.01).astype(np.int8),
        "category": rng.integers(0, 64, size=n).astype(np.int32),
        "creator_id": rng.integers(0, 5_000, size=n).astype(np.int64),
    }


@pytest.mark.parametrize("n", [0, 1, 7, 256, 1000])
def test_roundtrip_all_traits(n):
    batch = _random_batch(n)
    blob = columnar.encode_stripe(batch, SCHEMA)
    out = columnar.decode_stripe(blob, SCHEMA)
    assert set(out) == set(batch)
    for k in batch:
        np.testing.assert_array_equal(out[k], batch[k], err_msg=k)
        assert out[k].dtype == batch[k].dtype


def test_roundtrip_compressed():
    pytest.importorskip("zstandard")
    batch = _random_batch(512)
    blob = columnar.encode_stripe(batch, SCHEMA, compress=True)
    out = columnar.decode_stripe(blob, SCHEMA)
    for k in batch:
        np.testing.assert_array_equal(out[k], batch[k])


def test_selective_decode_only_requested():
    batch = _random_batch(128)
    blob = columnar.encode_stripe(batch, SCHEMA)
    out = columnar.decode_stripe(blob, SCHEMA, traits=("timestamp", "item_id"))
    assert set(out) == {"timestamp", "item_id"}
    np.testing.assert_array_equal(out["item_id"], batch["item_id"])


def test_selective_decode_touches_fewer_bytes():
    batch = _random_batch(1024)
    blob = columnar.encode_stripe(batch, SCHEMA)
    full = columnar.decoded_bytes_for(blob)
    partial = columnar.decoded_bytes_for(blob, ("timestamp", "item_id"))
    assert 0 < partial < full


def test_density_aware_encodings_beat_raw():
    batch = _random_batch(4096)
    blob = columnar.encode_stripe(batch, SCHEMA)
    raw = sum(v.nbytes for v in batch.values())
    assert len(blob) < raw  # trait-aware codec must win on realistic densities
    # sparse flags should land in bitmaps, timestamps in deltas
    header, _ = columnar._read_header(blob)
    codecs = {c["name"]: c["codec"] for c in header["cols"]}
    assert codecs["like"] == "bitmap"
    assert codecs["timestamp"] == "delta"
    assert codecs["action_type"] == "dict"


def test_stripe_num_events():
    batch = _random_batch(77)
    blob = columnar.encode_stripe(batch, SCHEMA)
    assert columnar.stripe_num_events(blob) == 77


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=0, max_value=300),
    density=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_property_roundtrip_sparse_flag(n, density, seed):
    rng = np.random.default_rng(seed)
    arr = (rng.random(n) < density).astype(np.int8)
    payload, meta = columnar.encode_column(arr, ev.SPARSE_FLAG)
    out = columnar.decode_column(payload, meta, np.dtype(np.int8))
    np.testing.assert_array_equal(out, arr)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=300),
    lo=st.integers(min_value=-(2**40), max_value=2**40),
    span=st.integers(min_value=0, max_value=2**33),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_property_roundtrip_monotone(n, lo, span, seed):
    rng = np.random.default_rng(seed)
    arr = np.sort(rng.integers(lo, lo + span + 1, size=n)).astype(np.int64)
    payload, meta = columnar.encode_column(arr, ev.DENSE_MONOTONE)
    out = columnar.decode_column(payload, meta, np.dtype(np.int64))
    np.testing.assert_array_equal(out, arr)


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=200),
    vocab=st.integers(min_value=1, max_value=10_000),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_property_roundtrip_categorical(n, vocab, seed):
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, vocab, size=n).astype(np.int32)
    payload, meta = columnar.encode_column(arr, ev.CATEGORICAL)
    out = columnar.decode_column(payload, meta, np.dtype(np.int32))
    np.testing.assert_array_equal(out, arr)


def test_checksum_changes_on_corruption():
    batch = _random_batch(64)
    c1 = columnar.stripe_checksum(batch)
    batch["item_id"] = batch["item_id"].copy()
    batch["item_id"][3] += 1
    assert columnar.stripe_checksum(batch) != c1
