"""Qwen3-8B [hf:Qwen/Qwen3-8B]: 36L d4096 32H GQA(kv=8) d_ff 12288 v151936,
qk-norm."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="qwen3-8b", n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab=151_936, head_dim=128, qk_norm=True, rope_theta=1e6,
)

SMOKE = TransformerConfig(
    name="qwen3-8b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=211, head_dim=16, qk_norm=True, rope_theta=1e6,
    compute_dtype=jnp.float32, q_chunk=16, loss_chunk=16,
)


def spec() -> ArchSpec:
    return ArchSpec("qwen3-8b", "lm", FULL, SMOKE, LM_SHAPES)
