"""O2O consistency + future-leakage auditing (paper §2.1, §3.3).

These checks back the paper's correctness argument:
  * no event with timestamp > T_request may appear in a training-time UIH
    (future-leakage prevention by temporal predicate);
  * the reconstructed UIH must equal the inference-time UIH exactly
    (checksum-validated in production; exact column compare here).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core import events as ev
from repro.core.materialize import Materializer
from repro.core.projection import TenantProjection
from repro.core.versioning import TrainingExample


def future_leakage_count(uih: ev.EventBatch, request_ts: int) -> int:
    if not uih or "timestamp" not in uih or ev.batch_len(uih) == 0:
        return 0
    return int(np.count_nonzero(uih["timestamp"] > request_ts))


def batches_equal(a: ev.EventBatch, b: ev.EventBatch) -> bool:
    if set(a.keys()) != set(b.keys()):
        return False
    return all(np.array_equal(a[k], b[k]) for k in a)


def project_reference(
    reference: ev.EventBatch,
    projection: Optional[TenantProjection],
    schema: ev.TraitSchema,
) -> ev.EventBatch:
    """Apply a tenant projection to a ground-truth UIH (for comparisons)."""
    if projection is None:
        return reference
    traits = [t for t in projection.all_traits(schema) if t in reference]
    out = ev.project_traits(reference, traits)
    n = ev.batch_len(out)
    if n > projection.seq_len:
        out = ev.slice_batch(out, n - projection.seq_len, n)
    return out


@dataclasses.dataclass
class AuditReport:
    examples: int = 0
    o2o_mismatches: int = 0
    leaked_examples: int = 0
    leaked_events: int = 0

    @property
    def clean(self) -> bool:
        return self.o2o_mismatches == 0 and self.leaked_events == 0


def audit(
    examples: Sequence[TrainingExample],
    references: Sequence[ev.EventBatch],
    materializer: Materializer,
    schema: ev.TraitSchema,
    projection: Optional[TenantProjection] = None,
    batched: bool = False,
) -> AuditReport:
    """Compare training-time materialization against inference-time ground truth.

    ``references[i]`` must be the complete UIH the ranking model saw for
    ``examples[i]`` at T_request (captured via ``BaseSnapshotter.inference_uih``).
    With ``batched=True`` the planned ``materialize_batch`` path is audited
    instead of per-example ``materialize`` — both must stay O2O-clean."""
    report = AuditReport()
    if batched:
        outputs = materializer.materialize_batch(examples, projection)
    else:
        outputs = (materializer.materialize(e, projection) for e in examples)
    for (exm, ref), got in zip(zip(examples, references), outputs):
        _check_one(report, exm, ref, got, projection, schema)
    return report


def _check_one(report, exm, ref, got, projection, schema) -> None:
    want = project_reference(ref, projection, schema)
    report.examples += 1
    if not batches_equal(got, want):
        report.o2o_mismatches += 1
    leaks = future_leakage_count(got, exm.request_ts)
    if leaks:
        report.leaked_examples += 1
        report.leaked_events += leaks


def audit_streaming(
    micro_batches: Iterable[Sequence[TrainingExample]],
    references_by_id: Dict[int, ev.EventBatch],
    materializer: Materializer,
    schema: ev.TraitSchema,
    projection: Optional[TenantProjection] = None,
    ack: Optional[Callable[[Sequence[TrainingExample]], None]] = None,
) -> AuditReport:
    """Streaming-mode audit (§3.2): materialize micro-batches AS THEY ARRIVE —
    compaction may publish new generations between (or during) micro-batches,
    which is exactly the condition the bifurcated protocol must survive.

    ``micro_batches`` is typically ``StreamingSource.micro_batches()`` running
    against a live stream; ``references_by_id`` maps ``request_id`` to the
    inference-time ground truth (stream consumption interleaves users, so
    positional pairing is not available); ``ack`` (e.g. ``StreamingSource.ack``)
    releases the examples' generation leases after each audited micro-batch —
    the audit then also exercises lease GC under churn."""
    report = AuditReport()
    for mb in micro_batches:
        outputs = materializer.materialize_batch(list(mb), projection)
        for exm, got in zip(mb, outputs):
            _check_one(report, exm, references_by_id[exm.request_id], got,
                       projection, schema)
        if ack is not None:
            ack(mb)
    return report
