"""Documentation drift guard (DESIGN.md §13).

The telemetry section promises an EXHAUSTIVE cross-reference: every field of
every ``*Stats`` dataclass in ``src/repro`` maps to a registry series (or is
explicitly called out as not adapter-published), and every directly
registered metric name is documented.  These tests walk the live code — new
counters or metrics added without a DESIGN.md row fail tier-1 instead of
rotting the docs.
"""
import dataclasses
import importlib
import inspect
import pkgutil
import re
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
DESIGN = (REPO_ROOT / "DESIGN.md").read_text()
SRC = REPO_ROOT / "src" / "repro"


def _all_stats_classes():
    import repro

    out = {}
    for mod in pkgutil.walk_packages(repro.__path__, "repro."):
        m = importlib.import_module(mod.name)   # import errors ARE failures
        for name, obj in vars(m).items():
            if (inspect.isclass(obj) and dataclasses.is_dataclass(obj)
                    and name.endswith("Stats")
                    and obj.__module__ == m.__name__):
                out[name] = obj
    return out


def test_every_stats_field_documented_in_design():
    classes = _all_stats_classes()
    assert len(classes) >= 13, sorted(classes)   # the §13 inventory
    missing = []
    for cls_name, cls in sorted(classes.items()):
        for f in dataclasses.fields(cls):
            if f"{cls_name}.{f.name}" not in DESIGN:
                missing.append(f"{cls_name}.{f.name}")
    assert not missing, (
        "DESIGN.md §13 cross-reference is missing *Stats fields "
        f"(add a mapping row or a not-published note): {missing}")


# a directly registered metric: counter/gauge/histogram( "repro_..."
# possibly with the name literal on the following line
_METRIC_RE = re.compile(
    r"(?:counter|gauge|histogram)\(\s*\n?\s*\"(repro_[a-z0-9_]+)\"")


def test_every_registered_metric_name_documented_in_design():
    names = set()
    for path in SRC.rglob("*.py"):
        names.update(_METRIC_RE.findall(path.read_text()))
    # the adapter's f-string families are covered by the naming rule + the
    # cross-reference table; this walk catches the directly named metrics
    assert "repro_stage_seconds" in names       # the walk itself works
    assert "repro_store_rtt_seconds" in names
    missing = sorted(n for n in names if n not in DESIGN)
    assert not missing, (
        f"DESIGN.md §13 is missing registered metric names: {missing}")


_EVENT_RE = re.compile(r"""(?:events\.emit|_emit)\(\s*\n?\s*"([a-z_]+)\"""")


def test_every_emitted_event_kind_documented_in_design():
    kinds = set()
    for path in SRC.rglob("*.py"):
        kinds.update(_EVENT_RE.findall(path.read_text()))
    # breaker transitions are emitted via an f-string on the state name
    kinds.update({"breaker_open", "breaker_half_open", "breaker_closed"})
    assert "generation_flip" in kinds and "worker_restart" in kinds
    missing = sorted(k for k in kinds if f"`{k}`" not in DESIGN)
    assert not missing, (
        f"DESIGN.md §13 event-kind list is missing: {missing}")
