"""Batch→stream catch-up handoff (paper §3.2).

A trainer that starts (or restarts) behind the live edge first **replays
warehouse hours** — the batch tier, user-bucketed, cheap sequential reads —
then **flips to live stream consumption**, with an exactly-once guarantee at
the flip:

  * ``request_id``s are allocated monotonically in request-arrival order, and
    warehouse hours partition that order, so the largest replayed id is a
    **watermark**: every id <= watermark has been trained from the warehouse;
  * the live phase drops stream examples with ``request_id <= watermark``
    (they are the same examples, republished on the other leg of the
    bifurcated pipeline) and releases their generation leases;
  * everything above the watermark is trained exactly once, from the stream.

The replayed hour range is captured at **construction time** and must be
sealed (no concurrent ingestion into those hours): construct the coordinator
while the warehouse head is a finished hour, then start live traffic. Hours
inside the range with no data read as empty — the sweep is contiguous and
gap-tolerant.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence

from repro.core.versioning import TrainingExample
from repro.storage.stream import Warehouse
from repro.streaming.source import StreamingSource


@dataclasses.dataclass(frozen=True)
class ReplayFilter:
    """One crash epoch's exactly-once exclusion (crash-safe resume, §10).

    A killed trainer's ``Feed.checkpoint`` records, per run, what was already
    trained: a PREFIX of the warehouse replay order (``skip_rows`` — rows
    trained while backfilling) plus a request-id INTERVAL ``(drop_lo,
    drop_hi]`` (rows trained from the live stream after the flip; live ids
    arrive monotonically, so the trained set is exactly an id interval above
    that epoch's replay watermark). On restart the coordinator re-replays the
    (now longer) warehouse sweep with the filter chain applied in crash-epoch
    order: each filter sees only rows that survived the earlier epochs'
    filters, so repeated kill/resume cycles compose. Rows in an epoch's old
    replay range have ids <= that epoch's watermark ``drop_lo`` and can never
    be interval-dropped by it — prefix counting stays exact."""

    skip_rows: int = 0
    drop_lo: int = -1     # exclusive lower bound of the trained-live interval
    drop_hi: int = -1     # inclusive upper bound; hi < lo disables

    def to_state(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_state(cls, d: dict) -> "ReplayFilter":
        return cls(skip_rows=int(d.get("skip_rows", 0)),
                   drop_lo=int(d.get("drop_lo", -1)),
                   drop_hi=int(d.get("drop_hi", -1)))


@dataclasses.dataclass
class BackfillStats:
    hours_replayed: int = 0
    empty_hours: int = 0
    warehouse_examples: int = 0
    stream_examples: int = 0
    duplicates_skipped: int = 0   # stream copies of warehouse-trained examples
    resume_skipped: int = 0       # rows excluded by resume ReplayFilters
    watermark: int = -1           # largest request_id trained from the warehouse
    flipped: bool = False         # reached the live phase


class BackfillCoordinator:
    """Replay ``warehouse`` hours up to the (sealed) head, then flip to live
    consumption from ``source`` — one unified micro-batch iterator a
    ``DPPWorkerPool`` can drain via ``start_stream``."""

    def __init__(
        self,
        warehouse: Warehouse,
        source: StreamingSource,
        micro_batch: int = 32,
        start_hour: Optional[int] = None,
        end_hour: Optional[int] = None,
        resume_filters: Sequence[ReplayFilter] = (),
    ):
        self.warehouse = warehouse
        self.source = source
        self.micro_batch = micro_batch
        hours = warehouse.hours()
        # the replay range is FROZEN here: [start_hour, end_hour] must be
        # sealed before live traffic starts, or the watermark under-covers
        self.start_hour = start_hour if start_hour is not None else (
            hours[0] if hours else 0)
        self.end_hour = end_hour if end_hour is not None else (
            hours[-1] if hours else self.start_hour - 1)
        # crash-safe resume: one filter per prior kill, oldest first. Mutable
        # per-filter prefix counters live here, not in the frozen filters.
        self._filters: List[List] = [[f, 0] for f in resume_filters]
        self.stats = BackfillStats()
        # optional repro.obs.Telemetry (control-plane events)
        self.telemetry = None

    # -- resume filter chain ---------------------------------------------------
    def _replay_drops(self, exm: TrainingExample) -> bool:
        """True iff a prior crash epoch already trained this replay row. Each
        filter only sees rows that survived the earlier epochs (the chain
        reproduces each epoch's own input sequence)."""
        for entry in self._filters:
            f: ReplayFilter = entry[0]
            if f.drop_lo < exm.request_id <= f.drop_hi:
                return True        # trained from the live stream that epoch
            if entry[1] < f.skip_rows:
                entry[1] += 1
                return True        # trained during that epoch's backfill
        return False

    def _interval_drops(self, request_id: int) -> bool:
        """Live-phase belt-and-braces: a prior epoch's live-trained id that
        somehow reappears on the stream must still be dropped exactly-once."""
        return any(f.drop_lo < request_id <= f.drop_hi
                   for f, _ in self._filters)

    def micro_batches(self) -> Iterator[List[TrainingExample]]:
        st = self.stats
        # -- phase 1: warehouse replay (contiguous, gap-tolerant hour sweep) --
        buf: List[TrainingExample] = []
        for hour in range(self.start_hour, self.end_hour + 1):
            empty = True
            for bucket in self.warehouse.iter_bucketed(hour):
                for exm in bucket:
                    empty = False
                    # the watermark covers SKIPPED rows too: they trained in a
                    # prior epoch, so their stream copies must still dedupe
                    if exm.request_id > st.watermark:
                        st.watermark = exm.request_id
                    if self._replay_drops(exm):
                        st.resume_skipped += 1
                        continue
                    st.warehouse_examples += 1
                    buf.append(exm)
                    if len(buf) >= self.micro_batch:
                        yield buf
                        buf = []
            st.hours_replayed += 1
            if empty:
                st.empty_hours += 1
        if buf:
            yield buf
        st.flipped = True
        if self.telemetry is not None:
            self.telemetry.events.emit(
                "backfill_flip", watermark=st.watermark,
                hours_replayed=st.hours_replayed,
                warehouse_examples=st.warehouse_examples)
        # -- phase 2: live stream, exactly-once across the flip ---------------
        for mb in self.source.micro_batches():
            keep: List[TrainingExample] = []
            for exm in mb:
                if (exm.request_id <= st.watermark
                        or self._interval_drops(exm.request_id)):
                    st.duplicates_skipped += 1
                    self.source.discard(exm)   # release its lease; it already
                    continue                   # trained from the warehouse
                st.stream_examples += 1
                keep.append(exm)
            if keep:
                yield keep
