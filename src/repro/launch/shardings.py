"""Parameter / optimizer-state / input PartitionSpec rules per model family.

Conventions (DESIGN.md §3):
  * LM dense weights: Megatron TP on the ``model`` axis (column-parallel in
    projections, row-parallel out), vocab-parallel embedding/unembedding.
  * MoE expert weights: expert dim on ``model`` + FSDP (ZeRO-3) sharding of the
    per-expert d_ff dim over ``data`` — the shard_map entry all-gathers them
    per layer inside the scan.
  * Optimizer moments: parameter spec + ZeRO sharding of the first divisible
    unsharded dim over ``data`` (ZeRO-2).
  * RecSys embedding tables: row-sharded over ALL mesh axes.
  * GNN: parameters replicated (tiny), edges sharded over all axes, nodes
    replicated (vertex-cut partitioning).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import all_axes_of, data_axes_of


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# LM params
# ---------------------------------------------------------------------------

def lm_param_specs(params_shape, mesh, moe_2d: bool = False) -> Any:
    """Spec tree matching the transformer param pytree (stacked blocks).

    ``moe_2d``: decode layout — expert weights fully sharded over
    (model x data) so no per-step FSDP all-gather is needed."""

    def rule(path, leaf) -> P:
        name = _path_str(path)
        nd = len(leaf.shape)
        if name in ("embed", "unembed"):
            return P("model", None)                   # vocab-parallel
        if "blocks" not in name:
            return P()                                # final_norm etc.
        # stacked block leaves: leading L dim
        if name.endswith(("ln1", "ln2", "q_norm", "k_norm", "kv_norm")):
            return P(None, None)
        if name.endswith(("attn/wq", "attn/wk", "attn/wv")):
            return P(None, None, "model")             # column parallel heads
        if name.endswith("attn/wo"):
            return P(None, "model", None)             # row parallel
        # MLA
        if name.endswith(("attn/w_uk", "attn/w_uv")):
            return P(None, None, "model")
        if name.endswith(("attn/w_dkv", "attn/w_k_rope")):
            return P(None, None, None)
        # dense FFN
        if name.endswith(("ffn/w_gate", "ffn/w_up")):
            return P(None, None, "model")
        if name.endswith("ffn/w_down"):
            return P(None, "model", None)
        # MoE
        if name.endswith("ffn/router"):
            return P(None, None, None)
        if name.endswith("ffn/w_in"):                 # (L, E, d, 2f)
            return (P(None, "model", "data", None) if moe_2d
                    else P(None, "model", None, "data"))   # EP+2D vs EP+FSDP
        if name.endswith("ffn/w_out"):                # (L, E, f, d)
            return (P(None, "model", "data", None) if moe_2d
                    else P(None, "model", None, "data"))
        if name.endswith("ffn/shared_w_in"):
            return P(None, None, "model")
        if name.endswith("ffn/shared_w_out"):
            return P(None, "model", None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, params_shape)


# ---------------------------------------------------------------------------
# ZeRO sharding of optimizer moments
# ---------------------------------------------------------------------------

def zero_shard(spec: P, shape: Tuple[int, ...], mesh) -> P:
    """Add ``data`` sharding on the first unsharded dim whose size divides."""
    if "data" in [a for e in spec for a in (e if isinstance(e, tuple) else (e,))
                  if e is not None]:
        return spec
    data_size = int(np.prod([mesh.shape[a] for a in data_axes_of(mesh)]))
    dims = list(spec) + [None] * (len(shape) - len(spec))
    for i, (e, n) in enumerate(zip(dims, shape)):
        if e is None and n % data_size == 0 and n >= data_size:
            dims[i] = data_axes_of(mesh) if len(data_axes_of(mesh)) > 1 \
                else data_axes_of(mesh)[0]
            return P(*dims)
    return spec


def opt_specs(param_specs, params_shape, mesh) -> Any:
    """AdamWState spec: step replicated; m/v ZeRO-sharded."""
    from repro.train.optimizer import AdamWState

    mv = jax.tree.map(
        lambda s, l: zero_shard(s, l.shape, mesh), param_specs, params_shape
    )
    return AdamWState(step=P(), m=mv, v=jax.tree.map(lambda s: s, mv))


# ---------------------------------------------------------------------------
# RecSys / GNN params
# ---------------------------------------------------------------------------

def recsys_param_specs(params_shape, mesh) -> Any:
    axes = all_axes_of(mesh)

    def rule(path, leaf) -> P:
        name = _path_str(path)
        nd = len(leaf.shape)
        big_table = ("table" in name or name == "embed" or
                     name.startswith("sparse_tables"))
        if big_table and nd == 2 and leaf.shape[0] >= 8192:
            # rows on `model` only (replicated over data): the shard_map
            # embedding path gathers locally + psums the reduced bag; ZeRO
            # shards the optimizer moments over data
            return P("model", None)
        if "blocks" in name or "seq_blocks" in name:
            # recsys sequence encoders are TINY (d<=128, <=4 heads): model-
            # sharding them makes GSPMD thrash 17GB of resharding all-reduces
            # (see EXPERIMENTS.md SPerf) — replicate instead
            return P(*([None] * nd))
        if nd == 2 and leaf.shape[0] * leaf.shape[1] >= (1 << 22):
            return P(None, "model")                  # big dense MLP layers
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def gnn_param_specs(params_shape, mesh) -> Any:
    return jax.tree.map(lambda l: P(*([None] * len(l.shape))), params_shape)


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
