"""DPP worker (paper §4.2.1-4.2.2): the vectorized query-engine operator.

A worker executes the specialized index join — probe side = primary training
examples, build side = the immutable UIH store — then featurizes the result
into a *base batch* sized to fit the worker's memory budget. Pipelined I/O
prefetching overlaps the immutable lookup for batch N with the probe-side read
for batch N+1.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.core import events as ev
from repro.core.materialize import Materializer
from repro.core.projection import TenantProjection
from repro.core.versioning import TrainingExample
from repro.dpp.featurize import (
    FeatureSpec,
    JaggedFeatures,
    featurize,
    featurize_jagged,
)
from repro.obs.spans import current_span

ProbeFn = Callable[[int], Optional[List[TrainingExample]]]  # batch idx -> examples


@dataclasses.dataclass
class WorkerPlan:
    """Spec-compiled read plan for one worker: everything a ``DPPWorker``
    needs, bundled by the declarative compiler (``repro.data.open_feed``) so
    pipelines stop hand-wiring (materializer, projection, feature spec,
    schema) at every call site. ``make_materializer`` is a factory because
    materializers are thread-local by design (window cache + IO accounting):
    each pool worker gets its own."""

    projection: TenantProjection
    feature_spec: FeatureSpec
    schema: ev.TraitSchema
    make_materializer: Callable[[], Materializer]
    probe_latency_s: float = 0.0


class _ProbeError:
    """Exception captured in the probe producer thread, re-raised consumer-side."""

    def __init__(self, exc: BaseException):
        self.exc = exc


@dataclasses.dataclass
class WorkerStats:
    base_batches: int = 0
    examples: int = 0
    probe_time_s: float = 0.0     # primary training-table read
    lookup_time_s: float = 0.0    # immutable UIH multi-range scan
    featurize_time_s: float = 0.0
    total_time_s: float = 0.0
    # planned-scan savings, accumulated from the store's IOStats per lookup
    dedup_hits: int = 0           # requests answered by an in-plan twin
    decode_cache_hits: int = 0    # stripe decodes served from the decode LRU
    parallel_shards: int = 0      # cumulative shard fanout of batched scans
    # self-healing (pool-level recovery, merged in by merged_worker_stats)
    worker_restarts: int = 0      # workers that died mid-item and were replaced
    items_requeued: int = 0       # work items re-dispatched after a crash
    lease_recoveries: int = 0     # generation leases released by crash recovery

    @property
    def busy_time_s(self) -> float:
        return self.probe_time_s + self.lookup_time_s + self.featurize_time_s

    @property
    def waste_pct(self) -> float:
        """CPU idle share of wall time (paper's 'worker waste percentage')."""
        if self.total_time_s <= 0:
            return 0.0
        return max(0.0, 1.0 - self.busy_time_s / self.total_time_s) * 100.0


class DPPWorker:
    def __init__(
        self,
        materializer: Materializer,
        projection: TenantProjection,
        feature_spec: FeatureSpec,
        schema: ev.TraitSchema,
        probe_latency_s: float = 0.0,   # emulated primary-table read latency
    ):
        self.materializer = materializer
        self.projection = projection
        self.feature_spec = feature_spec
        self.schema = schema
        self.probe_latency_s = probe_latency_s
        self.stats = WorkerStats()

    @classmethod
    def from_plan(cls, plan: WorkerPlan) -> "DPPWorker":
        """Build a worker from a spec-compiled ``WorkerPlan`` (fresh
        materializer per call: thread-local by design)."""
        return cls(plan.make_materializer(), plan.projection,
                   plan.feature_spec, plan.schema,
                   probe_latency_s=plan.probe_latency_s)

    # -- single base batch -----------------------------------------------------
    def _lookup(self, examples: List[TrainingExample]) -> List[ev.EventBatch]:
        t0 = time.perf_counter()
        # materializer-local IO accounting: the store's global stats are
        # shared across workers, so deltas of them would mix in other
        # workers' concurrent traffic
        before = self.materializer.io_stats.snapshot()
        uihs = self.materializer.materialize_batch(examples, self.projection)
        d = self.materializer.io_stats.delta(before)
        self.stats.dedup_hits += d.dedup_hits
        self.stats.decode_cache_hits += d.decode_cache_hits
        self.stats.parallel_shards += d.parallel_shards
        t1 = time.perf_counter()
        self.stats.lookup_time_s += t1 - t0
        sp = current_span()
        if sp is not None:
            # decode runs on store-internal shard threads, so it folds into
            # the scan stage; the IOStats delta keeps its weight visible
            sp.stage("scan", t0, t1)
            sp.meta["bytes_scanned"] = sp.meta.get("bytes_scanned", 0) + d.bytes_scanned
            sp.meta["bytes_decoded"] = sp.meta.get("bytes_decoded", 0) + d.bytes_decoded
        return uihs

    def _featurize(self, examples, uihs) -> Dict[str, np.ndarray]:
        t0 = time.perf_counter()
        out = featurize(examples, uihs, self.feature_spec)
        t1 = time.perf_counter()
        self.stats.featurize_time_s += t1 - t0
        self.stats.base_batches += 1
        self.stats.examples += len(examples)
        sp = current_span()
        if sp is not None:
            sp.stage("featurize", t0, t1)
        return out

    def process(self, examples: List[TrainingExample]) -> Dict[str, np.ndarray]:
        return self._featurize(examples, self._lookup(examples))

    def process_jagged(self, examples: List[TrainingExample]) -> JaggedFeatures:
        """Materialize + featurize into the arena+offsets form, skipping the
        [B, L] densification — ``RebatchingClient.put_jagged`` scatters the
        arena straight into the slot (one copy instead of three)."""
        uihs = self._lookup(examples)
        t0 = time.perf_counter()
        out = featurize_jagged(examples, uihs, self.feature_spec)
        t1 = time.perf_counter()
        self.stats.featurize_time_s += t1 - t0
        self.stats.base_batches += 1
        self.stats.examples += len(examples)
        sp = current_span()
        if sp is not None:
            sp.stage("featurize", t0, t1)
        return out

    def _probe(self, probe: ProbeFn, idx: int) -> Optional[List[TrainingExample]]:
        t0 = time.perf_counter()
        out = probe(idx)
        if self.probe_latency_s and out is not None:
            time.sleep(self.probe_latency_s)
        self.stats.probe_time_s += time.perf_counter() - t0
        return out

    # -- serial execution (baseline for the prefetch benchmark) -----------------
    def run_serial(self, probe: ProbeFn) -> Iterator[Dict[str, np.ndarray]]:
        t_start = time.perf_counter()
        idx = 0
        while True:
            examples = self._probe(probe, idx)
            if examples is None:
                break
            uihs = self._lookup(examples)
            yield self._featurize(examples, uihs)
            idx += 1
        self.stats.total_time_s += time.perf_counter() - t_start

    # -- pipelined execution (paper §4.2.2) --------------------------------------
    def run_pipelined(self, probe: ProbeFn) -> Iterator[Dict[str, np.ndarray]]:
        """Overlap the immutable-store lookup for batch N with the probe-side
        read for batch N+1 using a single prefetch thread (double buffering).

        A probe failure in the producer thread is captured and re-raised here —
        a daemon thread dying silently would otherwise leave the consumer
        blocked on ``probe_q.get()`` forever."""
        t_start = time.perf_counter()
        probe_q: "queue.Queue" = queue.Queue(maxsize=2)

        def producer():
            idx = 0
            while True:
                try:
                    examples = self._probe(probe, idx)
                except BaseException as e:
                    probe_q.put(_ProbeError(e))
                    return
                probe_q.put(examples)
                if examples is None:
                    return
                idx += 1

        th = threading.Thread(target=producer, daemon=True)
        th.start()
        try:
            while True:
                examples = probe_q.get()
                if isinstance(examples, _ProbeError):
                    raise RuntimeError("probe producer failed") from examples.exc
                if examples is None:
                    break
                uihs = self._lookup(examples)
                yield self._featurize(examples, uihs)
            th.join()
        finally:
            self.stats.total_time_s += time.perf_counter() - t_start


def probe_from_list(
    examples: Sequence[TrainingExample], base_batch_size: int
) -> ProbeFn:
    def probe(idx: int) -> Optional[List[TrainingExample]]:
        lo = idx * base_batch_size
        if lo >= len(examples):
            return None
        return list(examples[lo : lo + base_batch_size])

    return probe
