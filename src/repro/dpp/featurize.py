"""Featurization: materialized UIH event batches -> fixed-shape training arrays.

Pads/truncates the jagged per-example sequences into dense [B, L] arrays with a
validity mask (host-side numpy mirror of the ``repro.kernels.jagged`` Pallas
device kernel — see DESIGN.md §3 on where the device path takes over).

Two implementations coexist:

  * the **vectorized** path (``featurize``, ``pad_sequences``): the jagged
    per-example columns are flattened into a single values *arena* plus an
    ``offsets`` vector — the exact layout ``kernels/jagged`` consumes on
    device — and the dense [B, L] pad + mask are built with ONE fancy-index
    scatter shared across all traits (no per-example Python loop);
  * the **reference** path (``featurize_reference``, ``pad_sequences_reference``):
    the seed per-example-loop implementation, kept as the golden oracle —
    tests/test_feed.py proves the vectorized path byte-identical to it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core import events as ev
from repro.core.versioning import TrainingExample

_EMPTY_I64 = np.zeros(0, np.int64)


@dataclasses.dataclass(frozen=True)
class FeatureSpec:
    """Frozen + hashable (sequence fields normalized to tuples) so it can
    live inside a frozen ``repro.data.DatasetSpec``."""

    seq_len: int                       # padded UIH length
    uih_traits: Sequence[str]          # traits to lift into [B, L] arrays
    candidate_fields: Sequence[str] = ("item_id",)
    label_fields: Sequence[str] = ("click",)

    def __post_init__(self):
        object.__setattr__(self, "uih_traits", tuple(self.uih_traits))
        object.__setattr__(self, "candidate_fields",
                           tuple(self.candidate_fields))
        object.__setattr__(self, "label_fields", tuple(self.label_fields))


# ---------------------------------------------------------------------------
# Jagged arena: flattened values + offsets (the kernels/jagged layout)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ScatterPlan:
    """Jagged layout of one base batch: clipped lengths + arena offsets.

    Built once per distinct per-example length signature and reused by every
    trait that shares it (the common case: all traits of a UIH batch are
    equal-length columns). ``mask`` is the [B, L] validity grid: a boolean
    scatter ``out[mask] = arena`` fills each row's valid span left-to-right
    with consecutive arena elements — exactly the per-example reference
    semantics, with ZERO per-example Python iterations (and the mask doubles
    as the batch's ``uih_mask`` output).
    """

    b: int
    seq_len: int
    left_align: bool
    lens: np.ndarray        # [B] int64, clipped to seq_len
    offsets: np.ndarray     # [B+1] int64 into the clipped arena
    _mask: Optional[np.ndarray] = None

    @property
    def total(self) -> int:
        return int(self.offsets[-1])

    @property
    def mask(self) -> np.ndarray:
        if self._mask is None:
            j = np.arange(self.seq_len)
            if self.left_align:
                self._mask = j < self.lens[:, None]
            else:
                self._mask = j >= (self.seq_len - self.lens)[:, None]
        return self._mask

    def scatter(self, arena: np.ndarray, out: Optional[np.ndarray] = None
                ) -> np.ndarray:
        """Densify ``arena`` into a fresh (or provided) [B, L] grid."""
        if out is None:
            out = np.zeros((self.b, self.seq_len), dtype=arena.dtype)
        if self.total:
            out[self.mask] = arena
        return out


def make_scatter_plan(raw_lens: np.ndarray, seq_len: int,
                      left_align: bool = False) -> ScatterPlan:
    lens = np.minimum(raw_lens.astype(np.int64), seq_len)
    b = len(lens)
    offsets = np.zeros(b + 1, np.int64)
    np.cumsum(lens, out=offsets[1:])
    return ScatterPlan(b=b, seq_len=seq_len, left_align=left_align,
                       lens=lens, offsets=offsets)


def arena_of(seqs: Sequence[np.ndarray], plan: ScatterPlan,
             dtype: np.dtype) -> np.ndarray:
    """Concatenate the kept (truncated-to-plan) tails into one flat arena."""
    if plan.total == 0:
        return np.zeros(0, dtype)
    tails = [s[-n:] if n else s[:0]
             for s, n in zip(seqs, plan.lens)]
    out = np.concatenate(tails)
    if out.dtype != dtype:
        out = out.astype(dtype)
    return out


@dataclasses.dataclass
class JaggedFeatures:
    """A featurized base batch in jagged (arena + offsets) form.

    ``values[trait]`` is the flat [total] arena of clipped sequence tails and
    ``offsets`` the shared [B+1] boundaries — directly consumable by
    ``repro.kernels.jagged.ops.jagged_to_padded`` on device; ``to_padded``
    is the host-side equivalent (single scatter, no loops).
    """

    values: Dict[str, np.ndarray]
    plan: ScatterPlan
    scalars: Dict[str, np.ndarray]   # per-example fields ([B])
    # per-trait plans; only differ from ``plan`` for traits that are missing
    # from some examples (schema evolution / partial projections)
    trait_plans: Optional[Dict[str, ScatterPlan]] = None

    @property
    def offsets(self) -> np.ndarray:
        return self.plan.offsets

    def plan_for(self, trait: str) -> ScatterPlan:
        if self.trait_plans is not None and trait in self.trait_plans:
            return self.trait_plans[trait]
        return self.plan

    def to_padded(self) -> Dict[str, np.ndarray]:
        p = self.plan
        batch: Dict[str, np.ndarray] = {
            "uih_len": p.lens.astype(np.int32)}
        for trait, arena in self.values.items():
            batch[f"uih_{trait}"] = self.plan_for(trait).scatter(arena)
        batch["uih_mask"] = p.mask if p.total else np.zeros(
            (p.b, p.seq_len), dtype=np.bool_)
        batch.update(self.scalars)
        return batch


# ---------------------------------------------------------------------------
# Vectorized path (default)
# ---------------------------------------------------------------------------

def pad_sequences(
    seqs: Sequence[np.ndarray], seq_len: int, dtype=None, left_align: bool = False
) -> np.ndarray:
    """Right-aligned (most-recent-last) pad/truncate to [B, seq_len].

    Vectorized: one concat of the kept tails + one fancy-index scatter."""
    b = len(seqs)
    dtype = dtype or (seqs[0].dtype if b else np.int64)
    out = np.zeros((b, seq_len), dtype=dtype)
    if b == 0:
        return out
    raw_lens = np.fromiter((len(s) for s in seqs), np.int64, count=b)
    plan = make_scatter_plan(raw_lens, seq_len, left_align=left_align)
    return plan.scatter(arena_of(seqs, plan, out.dtype), out)


def featurize_jagged(
    examples: Sequence[TrainingExample],
    uihs: Sequence[ev.EventBatch],
    spec: FeatureSpec,
) -> JaggedFeatures:
    """Build one base batch in arena+offsets form (no [B, L] densification).

    One ScatterPlan is shared by every trait whose per-example lengths match
    the batch lengths; traits missing from some examples (schema evolution /
    partial projections) fall back to a per-trait plan so ``to_padded`` stays
    byte-identical to the reference per-example path.
    """
    assert len(examples) == len(uihs)
    b = len(examples)
    raw_lens_l = [ev.batch_len(u) for u in uihs]
    raw_lens = np.asarray(raw_lens_l, np.int64) if b else np.zeros(0, np.int64)
    plan = make_scatter_plan(raw_lens, spec.seq_len)
    values: Dict[str, np.ndarray] = {}
    plans: Dict[str, ScatterPlan] = {}
    for trait in spec.uih_traits:
        cols = [u.get(trait, _EMPTY_I64) for u in uihs]
        dtype = cols[0].dtype if b else np.dtype(np.int64)
        if all(len(c) == n for c, n in zip(cols, raw_lens_l)):
            t_plan = plan
        else:  # trait missing from some examples: its own jagged structure
            t_plan = make_scatter_plan(
                np.asarray([len(c) for c in cols], np.int64), spec.seq_len)
        values[trait] = arena_of(cols, t_plan, dtype)
        plans[trait] = t_plan

    scalars: Dict[str, np.ndarray] = {}
    for f in spec.candidate_fields:
        scalars[f"cand_{f}"] = np.array(
            [e.candidate.get(f, 0) for e in examples], np.int64)
    for f in spec.label_fields:
        scalars[f"label_{f}"] = np.array(
            [e.labels.get(f, 0.0) for e in examples], np.float32)
    scalars["request_ts"] = np.array([e.request_ts for e in examples], np.int64)
    scalars["user_id"] = np.array([e.user_id for e in examples], np.int64)
    return JaggedFeatures(values=values, plan=plan, scalars=scalars,
                          trait_plans=plans)


def featurize(
    examples: Sequence[TrainingExample],
    uihs: Sequence[ev.EventBatch],
    spec: FeatureSpec,
) -> Dict[str, np.ndarray]:
    """Build one base batch of dense arrays from materialized UIH sequences.

    Vectorized: arena + shared scatter; byte-identical to
    ``featurize_reference`` (proven in tests/test_feed.py)."""
    return featurize_jagged(examples, uihs, spec).to_padded()


# ---------------------------------------------------------------------------
# Reference path (the seed implementation, kept as the golden oracle)
# ---------------------------------------------------------------------------

def pad_sequences_reference(
    seqs: Sequence[np.ndarray], seq_len: int, dtype=None, left_align: bool = False
) -> np.ndarray:
    """Seed per-example-loop pad/truncate (golden oracle for ``pad_sequences``)."""
    b = len(seqs)
    dtype = dtype or (seqs[0].dtype if b else np.int64)
    out = np.zeros((b, seq_len), dtype=dtype)
    for i, s in enumerate(seqs):
        s = s[-seq_len:]
        if left_align:
            out[i, : len(s)] = s
        else:
            out[i, seq_len - len(s):] = s
    return out


def featurize_reference(
    examples: Sequence[TrainingExample],
    uihs: Sequence[ev.EventBatch],
    spec: FeatureSpec,
) -> Dict[str, np.ndarray]:
    """Seed per-example-loop featurizer (golden oracle for ``featurize``)."""
    assert len(examples) == len(uihs)
    b = len(examples)
    lens = np.array([min(ev.batch_len(u), spec.seq_len) for u in uihs], np.int32)
    batch: Dict[str, np.ndarray] = {"uih_len": lens}
    for trait in spec.uih_traits:
        cols = [u.get(trait, np.zeros(0, np.int64)) for u in uihs]
        batch[f"uih_{trait}"] = pad_sequences_reference(cols, spec.seq_len)
    mask = np.zeros((b, spec.seq_len), dtype=np.bool_)
    for i, n in enumerate(lens):
        mask[i, spec.seq_len - n:] = True
    batch["uih_mask"] = mask
    for f in spec.candidate_fields:
        batch[f"cand_{f}"] = np.array(
            [e.candidate.get(f, 0) for e in examples], np.int64
        )
    for f in spec.label_fields:
        batch[f"label_{f}"] = np.array(
            [e.labels.get(f, 0.0) for e in examples], np.float32
        )
    batch["request_ts"] = np.array([e.request_ts for e in examples], np.int64)
    batch["user_id"] = np.array([e.user_id for e in examples], np.int64)
    return batch


def merge_base_batches(batches: Sequence[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    keys = batches[0].keys()
    return {k: np.concatenate([b[k] for b in batches], axis=0) for k in keys}


def reshuffle(batch: Dict[str, np.ndarray], seed: int) -> Dict[str, np.ndarray]:
    n = len(next(iter(batch.values())))
    perm = np.random.default_rng(seed).permutation(n)
    return {k: v[perm] for k, v in batch.items()}
