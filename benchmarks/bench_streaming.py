"""Streaming training throughput + freshness while compaction cycles (§3.2).

Measures the online half of the bifurcated protocol end-to-end: a producer
runs live traffic days (each with its daily compaction) PLUS an extra
generation-churn thread re-compacting the established watermark, while a
``StreamingSession`` backfills the warehouse, flips to the live stream with
the exactly-once watermark, and materializes generation-pinned windows into
full batches. Reported:

  * ``streaming_sustained`` — full-batch cadence; derived: rows/s, event->
    gradient freshness (mean/max ms), generation flips survived, pinned vs
    re-resolved window counts, checksum failures (must be 0);
  * ``streaming_handoff`` — warehouse catch-up replay rate and the flip's
    exactly-once accounting (duplicates skipped, watermark).
"""
from __future__ import annotations

import threading
import time

from benchmarks.common import BenchResult
from repro.core import events as ev
from repro.core.projection import TenantProjection
from repro.core.simulation import ProductionSim, SimConfig
from repro.dpp.featurize import FeatureSpec
from repro.dpp.worker import DPPWorker
from repro.streaming import MicroBatchConfig, StreamingSession

SEQ_LEN = 32


def run(quick: bool = False):
    users, hist_days, live_days, req = (6, 1, 1, 3) if quick else (24, 2, 2, 6)
    batch = 16 if quick else 32
    sim = ProductionSim(SimConfig(
        stream=ev.StreamConfig(
            n_users=users, n_items=4_000, days=hist_days + live_days + 1,
            events_per_user_day_mean=15.0 if quick else 40.0, seed=7),
        stripe_len=32, requests_per_user_day=req, seed=7,
        pin_generations=True))
    sim.run_days(hist_days, capture_reference=False)
    n_history = len(sim.examples)

    tenant = TenantProjection(
        "bench", seq_len=SEQ_LEN, feature_groups=("core", "sideinfo"),
        traits_per_group={"core": ("timestamp", "item_id", "action_type"),
                          "sideinfo": ("category",)})
    spec = FeatureSpec(seq_len=SEQ_LEN,
                       uih_traits=("item_id", "action_type", "category"),
                       candidate_fields=("item_id",), label_fields=("click",))

    def make_worker():
        mat = sim.materializer(validate_checksum=True, pin_generations=True)
        mat.window_cache_size = 128
        return DPPWorker(mat, tenant, spec, sim.schema)

    session = StreamingSession(
        sim.stream, make_worker, full_batch_size=batch,
        micro_batch=MicroBatchConfig(max_examples=8, max_delay_s=0.02),
        n_workers=2, backfill_from=sim.warehouse).start()

    gen_start = sim.immutable.generation
    stop = threading.Event()

    def churn():
        # generation churn under the in-flight stream: re-compact the
        # established watermark (identical content, new generation id)
        while not stop.is_set():
            if sim.compaction_watermark >= 0:
                sim.run_compaction(sim.compaction_watermark, evict=False)
            time.sleep(0.01)

    def producer():
        try:
            for day in range(hist_days, hist_days + live_days):
                sim.run_day(day, capture_reference=False)
        finally:
            sim.stream.close()

    churn_th = threading.Thread(target=churn, daemon=True)
    prod = threading.Thread(target=producer, daemon=True)
    churn_th.start()
    prod.start()

    t0 = time.perf_counter()
    rows = 0
    batches = 0
    backfill_done_t = None
    for b in session:
        batches += 1
        rows += len(b["uih_len"])
        if backfill_done_t is None and session.backfill_stats.flipped:
            backfill_done_t = time.perf_counter()
        session.record_train_step(0.0005)   # stand-in train step
        session.recycle(b)
    wall = time.perf_counter() - t0
    session.join()
    prod.join()
    stop.set()
    churn_th.join()

    bf = session.backfill_stats
    fr = session.freshness
    mats = [w.materializer for w in session.pool._workers]
    pinned = sum(m.stats.pinned_windows for m in mats)
    stale = sum(m.stats.stale_reresolved for m in mats)
    failures = sum(m.stats.stale_failures + m.stats.checksum_failures
                   for m in mats)
    flips = sim.immutable.generation - gen_start
    total = len(sim.examples)
    assert bf.warehouse_examples + bf.stream_examples == total, "lost examples"
    assert failures == 0, "stale remediation failed"

    results = [
        BenchResult(
            "streaming_sustained",
            us_per_call=wall / max(batches, 1) * 1e6,
            derived={
                "rows_per_s": round(rows / wall, 1),
                "rows": rows,
                "event_to_gradient_ms_mean":
                    round(fr.mean_event_to_gradient_s * 1e3, 1),
                "event_to_gradient_ms_max":
                    round(fr.event_to_gradient_s_max * 1e3, 1),
                "gen_flips": flips,
                "pinned_windows": pinned,
                "stale_reresolved": stale,
                "window_failures": failures,
                "leases_gc": sim.immutable.lease_stats.generations_gc,
                "peak_stream_lag": session.source.stats.max_lag,
            },
        ),
        BenchResult(
            "streaming_handoff",
            us_per_call=(
                ((backfill_done_t or t0) - t0) / max(n_history, 1) * 1e6),
            derived={
                "warehouse_examples": bf.warehouse_examples,
                "stream_examples": bf.stream_examples,
                "duplicates_skipped": bf.duplicates_skipped,
                "watermark": bf.watermark,
                "hours_replayed": bf.hours_replayed,
                "empty_hours": bf.empty_hours,
                "exactly_once": int(
                    bf.warehouse_examples + bf.stream_examples == total),
            },
        ),
    ]
    return results


if __name__ == "__main__":
    for r in run():
        print(r.csv())
