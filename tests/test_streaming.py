"""Streaming training subsystem (paper §3.2): bifurcated O2O protocol.

Covers the protocol's correctness spine:
  * generation leases retain superseded generations and GC on last release;
  * pinned materialization reproduces the logged window byte-exact even after
    a scrubbing compaction; unpinned remediation re-resolves + revalidates and
    raises ``StaleGeneration`` when the window genuinely changed;
  * deadline/size-bounded micro-batching with an unambiguous drain signal;
  * the batch→stream catch-up handoff trains every request_id exactly once;
  * STRESS: compaction cycling concurrently with snapshotting + streaming
    materialization keeps ``audit()`` clean across >= 2 generation flips, in
    both streaming and batch modes.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import events as ev
from repro.core.consistency import audit, audit_streaming
from repro.core.materialize import ChecksumMismatch, Materializer, StaleGeneration
from repro.core.projection import TenantProjection
from repro.dpp.featurize import FeatureSpec
from repro.dpp.worker import DPPWorker
from repro.storage.compaction import make_scrub
from repro.storage.immutable_store import (
    GenerationUnavailable,
    ImmutableUIHStore,
    ScanRequest,
    Stripe,
)
from repro.storage.mutable_store import MutableUIHStore
from repro.storage.stream import TrainingExampleStream, Warehouse
from repro.streaming import (
    BackfillCoordinator,
    MicroBatchConfig,
    StreamingSession,
    StreamingSource,
)


from conftest import make_sim, refs_by_id as _refs_by_id


def _sim(users=6, days=2, seed=0, req=3, mode="vlm"):
    # shared fixture builder (tests/conftest.py), always generation-pinned:
    # this file exercises the bifurcated streaming protocol
    return make_sim(users=users, days=days, seed=seed, req=req, mode=mode,
                    pin=True)


# ---------------------------------------------------------------------------
# satellites: stream drain signal, empty warehouse hours, evict cache reuse
# ---------------------------------------------------------------------------

def test_stream_drained_vs_timeout():
    stream = TrainingExampleStream(ev.default_schema(), capacity=8)
    assert stream.consume(timeout=0.01) is None   # timed out...
    assert not stream.drained                     # ...but NOT exhausted
    stream.close()
    assert stream.consume(timeout=0.01) is None
    assert stream.drained                         # closed AND empty


def test_warehouse_missing_hour_reads_empty():
    wh = Warehouse(ev.default_schema())
    assert wh.read_partition(123) == []
    assert list(wh.iter_bucketed(123)) == []
    assert wh.bytes_read == 0


def test_evict_until_reuses_merged_cache():
    schema = ev.default_schema()
    a = MutableUIHStore(schema)
    b = MutableUIHStore(schema)
    rng = np.random.default_rng(0)
    for uid in range(4):
        ts = np.sort(rng.integers(0, 1000, size=12))
        batch = {
            "timestamp": ts.astype(np.int64),
            "item_id": rng.integers(0, 50, size=12).astype(np.int64),
            "action_type": rng.integers(0, 4, size=12).astype(np.int64),
            "watch_pct": rng.random(12).astype(np.float32),
            "category": rng.integers(0, 8, size=12).astype(np.int64),
            "creator_id": rng.integers(0, 9, size=12).astype(np.int64),
        }
        for store in (a, b):
            store.append(uid, {k: v.copy() for k, v in batch.items()})
    # warm a's cache via the read path; b evicts cold
    for uid in range(4):
        a.read(uid, -1, 10_000)
    for store in (a, b):
        store.evict_all_until(500)
    assert a.evict_cache_hits == 4 and a.evict_merges == 0
    assert b.evict_cache_hits == 0 and b.evict_merges == 4
    for uid in range(4):
        got_a = a.read(uid, -1, 10_000)
        got_b = b.read(uid, -1, 10_000)
        for k in got_a:
            assert np.array_equal(got_a[k], got_b[k])
        if ev.batch_len(got_a):
            assert int(got_a["timestamp"].min()) > 500


# ---------------------------------------------------------------------------
# generation leases
# ---------------------------------------------------------------------------

def _tiny_tables(schema, n=8, t0=0):
    from repro.storage import columnar

    ts = np.arange(t0, t0 + n, dtype=np.int64)
    batch = {
        "timestamp": ts,
        "item_id": np.arange(n, dtype=np.int64) + t0,
        "action_type": np.zeros(n, dtype=np.int64),
    }
    blob = columnar.encode_stripe(
        {k: batch[k] for k in ("timestamp", "item_id", "action_type")}, schema)
    return {(0, "core"): [Stripe(start_ts=int(ts[0]), end_ts=int(ts[-1]),
                                 n_events=n, blob=blob)]}


def test_generation_lease_retain_and_gc():
    schema = ev.default_schema()
    store = ImmutableUIHStore(schema, n_shards=2)
    store.bulk_load(_tiny_tables(schema, t0=0), generation=0)
    lease = store.acquire_lease(0)
    store.bulk_load(_tiny_tables(schema, t0=100), generation=1)
    # gen 0 retained while leased; both generations scannable
    assert store.retained_generations() == [0]
    assert store.has_generation(0) and store.has_generation(1)
    old = store.scan(ScanRequest(0, "core", 0, 10**12, generation=0))
    new = store.scan(ScanRequest(0, "core", 0, 10**12, generation=-1))
    assert int(old["timestamp"][0]) == 0 and int(new["timestamp"][0]) == 100
    assert store.stats.pinned_scans == 1
    assert store.retained_bytes() > 0
    lease.release()
    assert store.retained_generations() == []
    assert store.lease_stats.generations_gc == 1
    with pytest.raises(GenerationUnavailable):
        store.scan(ScanRequest(0, "core", 0, 10**12, generation=0))
    lease.release()  # idempotent
    # unleased supersede drops the old generation immediately
    store.bulk_load(_tiny_tables(schema, t0=200), generation=2)
    assert store.retained_generations() == []
    assert not store.has_generation(1)


def test_lease_refcounting():
    schema = ev.default_schema()
    store = ImmutableUIHStore(schema, n_shards=2)
    store.bulk_load(_tiny_tables(schema), generation=0)
    l1, l2 = store.acquire_lease(0), store.acquire_lease(0)
    store.bulk_load(_tiny_tables(schema, t0=50), generation=1)
    l1.release()
    assert store.has_generation(0)      # second lease still pins it
    l2.release()
    assert not store.has_generation(0)
    with pytest.raises(GenerationUnavailable):
        store.acquire_lease(0)


# ---------------------------------------------------------------------------
# stale-generation remediation + pinned materialization
# ---------------------------------------------------------------------------

def test_pinned_materialization_survives_scrubbing_compaction():
    """A scrub that rewrites history between logging and training: the leased
    (pinned) path reproduces the ORIGINAL window byte-exact; the unpinned
    strict path raises StaleGeneration after failed re-resolution."""
    sim = _sim(days=2, seed=11)
    target = next(e for e in sim.examples if e.version.seq_len > 4)
    ref = sim.references[sim.examples.index(target)]
    assert sim.stream.pending_leases() > 0  # publisher pinned the generations

    baseline = sim.materializer(validate_checksum=True).materialize(target)
    item = int(np.bincount(baseline["item_id"]).argmax())
    sim.run_compaction(sim.immutable.watermark(target.user_id),
                       scrub=make_scrub(deleted_items=[item]))

    # pinned: byte-exact reproduction of the logged window
    pinned = sim.materializer(validate_checksum=True, pin_generations=True)
    got = pinned.materialize(target)
    for k in got:
        assert np.array_equal(got[k], baseline[k])
    assert pinned.stats.pinned_windows == 1
    assert pinned.stats.stale_failures == 0

    # drop the lease -> the generation is GC'd -> remediation must re-resolve
    # against the scrubbed live generation and refuse the drifted window
    sim.stream.release_leases()
    assert not sim.immutable.has_generation(target.version.generation)
    unpinned = sim.materializer(validate_checksum=True, pin_generations=True)
    with pytest.raises(StaleGeneration):
        unpinned.materialize(target)
    assert unpinned.stats.pin_misses == 1
    assert unpinned.stats.stale_failures == 1
    # ...and StaleGeneration is still a ChecksumMismatch for legacy handlers
    assert issubclass(StaleGeneration, ChecksumMismatch)


def test_stale_reresolve_is_clean_without_scrub():
    """Compaction without scrubs rebuilds identical windows: the re-resolve
    remediation validates and audit stays clean even with every lease gone."""
    sim = _sim(days=2, seed=5)
    sim.stream.release_leases()
    sim.run_compaction((sim.current_day + 1) * ev.MS_PER_DAY - 1)  # extra flip
    mat = sim.materializer(validate_checksum=True, pin_generations=True)
    report = audit(sim.examples, sim.references, mat, sim.schema)
    assert report.clean
    assert mat.stats.stale_reresolved > 0
    assert mat.stats.stale_failures == 0


# ---------------------------------------------------------------------------
# micro-batching
# ---------------------------------------------------------------------------

def test_micro_batch_size_and_deadline_flushes():
    sim = _sim(days=1, seed=3)
    src = StreamingSource(sim.stream,
                         MicroBatchConfig(max_examples=4, max_delay_s=0.03,
                                          poll_s=0.005))
    it = src.micro_batches()
    # backlog present -> size-bounded flushes
    mb = next(it)
    assert len(mb) == 4
    assert src.stats.size_flushes == 1
    # drain the backlog, then publish a lone trickle example: deadline flush
    backlog = []
    done = threading.Event()

    def drain_until_deadline_flush():
        for m in it:
            backlog.append(m)
            if src.stats.deadline_flushes:
                break
        done.set()

    th = threading.Thread(target=drain_until_deadline_flush, daemon=True)
    th.start()
    time.sleep(0.2)   # let the backlog drain; stream is now empty
    lone = sim.examples[0]
    t0 = time.perf_counter()
    sim.stream.publish(lone)
    done.wait(timeout=5.0)
    waited = time.perf_counter() - t0
    assert src.stats.deadline_flushes == 1
    assert len(backlog[-1]) < 4          # flushed short, by deadline
    assert waited < 1.0                   # and promptly
    sim.stream.close()
    th.join(timeout=2.0)
    # remaining iterator terminates on the drain signal
    rest = list(it)
    assert sim.stream.drained
    total = sum(len(m) for m in backlog + rest) + 4
    assert total == len(sim.examples) + 1  # lone example re-published


# ---------------------------------------------------------------------------
# batch->stream catch-up handoff
# ---------------------------------------------------------------------------

def test_backfill_handoff_exactly_once():
    sim = _sim(users=8, days=2, seed=7, req=4)
    n_history = len(sim.examples)
    src = StreamingSource(sim.stream, MicroBatchConfig(max_examples=8))
    coord = BackfillCoordinator(sim.warehouse, src, micro_batch=8)

    def producer():
        sim.run_day(2, capture_reference=True)   # live traffic + a gen flip
        sim.stream.close()

    th = threading.Thread(target=producer, daemon=True)
    th.start()
    trained = []
    for mb in coord.micro_batches():
        trained.extend(e.request_id for e in mb)
    th.join()
    st = coord.stats
    # every request_id exactly once: no drops, no double-training at the flip
    assert sorted(trained) == sorted(e.request_id for e in sim.examples)
    assert len(set(trained)) == len(trained)
    assert st.warehouse_examples == n_history
    assert st.duplicates_skipped == n_history    # stream copies of history
    assert st.stream_examples == len(sim.examples) - n_history > 0
    assert st.watermark == n_history - 1
    assert st.flipped
    # duplicate-skip released the history leases; live ones drain via ack
    src.ack([rid for rid in trained])
    assert sim.stream.pending_leases() == 0


def test_backfill_sweeps_contiguous_hours_with_gaps():
    """The replay range is a contiguous hour sweep; hours without data (the
    overnight gap between simulated days) read as empty, not KeyError."""
    sim = _sim(users=4, days=2, seed=9)
    src = StreamingSource(sim.stream, MicroBatchConfig(max_examples=16))
    sim.stream.close()
    coord = BackfillCoordinator(sim.warehouse, src, micro_batch=16)
    n = sum(len(mb) for mb in coord.micro_batches())
    hours = sim.warehouse.hours()
    assert coord.stats.hours_replayed == hours[-1] - hours[0] + 1
    assert coord.stats.empty_hours > 0
    assert coord.stats.warehouse_examples == len(sim.examples)
    # everything was replayed from the warehouse; stream copies all deduped
    assert n == len(sim.examples)


# ---------------------------------------------------------------------------
# STRESS: concurrent compaction vs snapshotting + materialization (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_streaming_audit_clean_under_concurrent_compaction():
    """Compaction publishes new generations WHILE traffic is being snapshotted
    and a streaming consumer materializes in micro-batches. The audit must
    stay clean (0 leaks, 0 O2O mismatches) across >= 2 generation flips, in
    streaming mode during the run and batch mode after it."""
    sim = _sim(users=6, days=1, seed=13, req=4)
    gen_start = sim.immutable.generation
    flips = [0]
    # the producer publishes the established watermark; the churn thread
    # re-compacts at exactly that watermark — identical window content, fresh
    # generation id every time (pure generation churn under in-flight
    # examples: the adversarial case for the lease protocol). A watermark
    # that regressed or ran ahead would be a DIFFERENT pipeline bug, not the
    # one under test.
    wm_box = [1 * ev.MS_PER_DAY - 1]   # day-1 boundary: the next cycle's mark
    stop = threading.Event()

    def churn():
        while not stop.is_set():
            sim.run_compaction(wm_box[0], evict=False)
            flips[0] += 1
            time.sleep(0.004)

    def producer():
        try:
            for day in (1, 2):
                wm = day * ev.MS_PER_DAY - 1
                sim.run_compaction(wm)
                wm_box[0] = wm
                sim.ingest_day_events(day)
                sim.issue_requests(day, capture_reference=True)
                sim.current_day = day
        finally:
            sim.stream.close()

    comp = threading.Thread(target=churn, daemon=True)
    prod = threading.Thread(target=producer, daemon=True)
    comp.start()
    prod.start()
    # compaction churns CONCURRENTLY with snapshotting for the whole producer
    # phase; the consumer starts against the accumulated backlog so that every
    # queued example is guaranteed stale (its generation superseded many times
    # over) while its lease still pins the original window — and churn keeps
    # flipping generations CONCURRENTLY with materialization below
    prod.join()
    assert sim.stream.pending_leases() > 0
    assert sim.immutable.retained_generations()   # leases held gens alive

    src = StreamingSource(sim.stream, MicroBatchConfig(max_examples=8))
    mat = sim.materializer(validate_checksum=True, pin_generations=True)
    refs = {e.request_id: r
            for e, r in zip(sim.examples, sim.references)}
    report = audit_streaming(src.micro_batches(), refs, mat,
                             sim.schema, ack=src.ack)
    stop.set()
    comp.join()

    assert report.examples == len(sim.examples)
    assert report.clean, (report, mat.stats)
    assert flips[0] >= 2
    assert sim.immutable.generation - gen_start >= 2
    # streaming consumed+acked everything: no lease outlives its example,
    # and the retained-generation set fully drains
    assert sim.stream.pending_leases() == 0
    assert sim.immutable.retained_generations() == []
    # the backlog's windows materialized byte-exact from lease-retained
    # generations (the pinned path really ran)
    assert mat.stats.pinned_windows > 0
    assert mat.stats.stale_failures == 0
    assert sim.immutable.lease_stats.generations_gc > 0

    # batch mode over the same traffic, AFTER all the churn (planned path)
    batch_report = audit(sim.examples, sim.references,
                         sim.materializer(validate_checksum=True,
                                          pin_generations=True),
                         sim.schema, batched=True)
    assert batch_report.clean


def test_session_drops_stale_examples_and_survives():
    """A genuine window change (scrub) mid-stream must DROP the affected
    examples — leases released, counted — while the session keeps training
    the rest; it must not kill worker threads."""
    sim = _sim(users=6, days=2, seed=21, req=3)
    # make every in-flight window genuinely stale: release all pins, then
    # re-compact with a scrub that rewrites history
    sim.stream.release_leases()
    uih = sim.materializer(validate_checksum=False).materialize(
        next(e for e in sim.examples if e.version.seq_len > 4))
    item = int(np.bincount(uih["item_id"]).argmax())
    sim.run_compaction(sim.compaction_watermark,
                       scrub=make_scrub(deleted_items=[item]))

    tenant = TenantProjection(
        "t", seq_len=24, feature_groups=("core",),
        traits_per_group={"core": ("timestamp", "item_id", "action_type")})
    spec = FeatureSpec(seq_len=24, uih_traits=("item_id", "action_type"))

    def make_worker():
        mat = sim.materializer(validate_checksum=True, pin_generations=True)
        return DPPWorker(mat, tenant, spec, sim.schema)

    session = StreamingSession(
        sim.stream, make_worker, full_batch_size=8,
        micro_batch=MicroBatchConfig(max_examples=8, max_delay_s=0.02),
        n_workers=2).start()
    sim.stream.close()

    rows = 0
    for batch in session:
        rows += len(batch["uih_len"])
    session.join()   # must not raise: stale examples were dropped, not fatal

    total = len(sim.examples)
    assert session.stale_dropped > 0          # the scrub really bit
    assert rows + session.stale_dropped >= (total // 8) * 8  # rest trained
    assert sim.stream.pending_leases() == 0   # dropped examples released too
    mats = [w.materializer for w in session.pool._workers]
    assert sum(m.stats.stale_failures for m in mats) > 0


def test_pool_join_unblocks_after_total_worker_failure():
    """All workers dying on a LIVE feed must not hang join(): the feeder is
    parked on the bounded item queue and has to detect the dead pool, so the
    worker error surfaces (and the client gets closed) instead of deadlock."""
    from repro.dpp.elastic import DPPWorkerPool

    class _Stats:
        total_time_s = 0.0
        busy_time_s = 0.0

    class _BadWorker:
        def __init__(self):
            self.stats = _Stats()

        def process(self, item):
            raise ValueError("boom")

    closed = []

    class _Client:
        stats = None

        def put(self, b):
            pass

        def close(self):
            closed.append(True)

    def live_items():
        while True:   # never-ending source: only the dead-pool check stops it
            yield [1, 2, 3]

    pool = DPPWorkerPool(lambda: _BadWorker(), _Client(), n_workers=2,
                         jagged=False)
    pool.start_stream(live_items(), max_buffered=4)
    with pytest.raises(RuntimeError):
        pool.join()
    assert closed  # end-of-stream sentinel path still ran


# ---------------------------------------------------------------------------
# full streaming session: pool + client + freshness + exactly-once
# ---------------------------------------------------------------------------

def test_streaming_session_end_to_end():
    sim = _sim(users=8, days=2, seed=1, req=4)
    tenant = TenantProjection(
        "t", seq_len=24, feature_groups=("core",),
        traits_per_group={"core": ("timestamp", "item_id", "action_type")})
    spec = FeatureSpec(seq_len=24, uih_traits=("item_id", "action_type"))
    trained_ids = []
    ids_lock = threading.Lock()

    class _TrackingWorker(DPPWorker):
        def process_jagged(self, examples):
            with ids_lock:
                trained_ids.extend(e.request_id for e in examples)
            return super().process_jagged(examples)

    def make_worker():
        mat = sim.materializer(validate_checksum=True, pin_generations=True)
        return _TrackingWorker(mat, tenant, spec, sim.schema)

    session = StreamingSession(
        sim.stream, make_worker, full_batch_size=16,
        micro_batch=MicroBatchConfig(max_examples=8, max_delay_s=0.02),
        n_workers=2, backfill_from=sim.warehouse).start()

    def producer():
        sim.run_day(2, capture_reference=False)
        sim.stream.close()

    prod = threading.Thread(target=producer, daemon=True)
    prod.start()

    rows = 0
    for batch in session:
        assert batch["uih_item_id"].shape[1] == 24
        rows += len(batch["uih_len"])
        session.record_train_step(0.0005)
        session.recycle(batch)
    session.join()
    prod.join()

    total = len(sim.examples)
    st = session.backfill_stats
    assert st.warehouse_examples + st.stream_examples == total
    assert sorted(trained_ids) == sorted(e.request_id for e in sim.examples)
    assert rows == (total // 16) * 16 + total % 16   # tail flushed too
    # freshness metrics populated for the live phase
    fr = session.freshness
    assert fr.batches_delivered > 0 and fr.samples > 0
    assert fr.event_to_gradient_s_max >= fr.mean_event_to_gradient_s > 0
    assert session.source.stats.micro_batches > 0
    # drained: every lease released, nothing retained
    assert sim.stream.pending_leases() == 0
    assert sim.immutable.retained_generations() == []
