"""Fault-tolerant checkpointing: atomic sharded save/restore with keep-k
retention, auto-resume, and elastic resharding to a different mesh."""
