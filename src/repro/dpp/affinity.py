"""Data-affinity planning for batch training (paper §4.2.3).

Two complementary strategies:
  1. user bucketing at warehouse-ingestion time (see ``storage.stream.Warehouse``)
     groups a user's temporally-adjacent examples so one immutable lookup is
     amortized across them (``Materializer.materialize_batch`` exploits it);
  2. symmetric sharding: the warehouse bucket key equals the immutable store's
     partition key, so a bucket's lookups hit exactly one shard (zero fanout).

This module plans DPP work assignments honoring both.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

from repro.core.versioning import TrainingExample
from repro.storage.sharding import shard_of


@dataclasses.dataclass
class AffinityPlan:
    # work items: each is a list of examples a single DPP worker processes
    items: List[List[TrainingExample]]
    expected_fanout: float            # avg distinct shards per item
    amortizable_pairs: int            # adjacent same-(user,window) example pairs


def plan_affine(
    examples: Sequence[TrainingExample],
    n_shards: int,
    base_batch_size: int,
) -> AffinityPlan:
    """User-clustered plan: sort by (shard, user, request_ts, request_id) —
    a TOTAL order, so the plan is invariant under input permutation — and cut
    into base batches at shard boundaries. All lookups in an item target
    exactly ONE shard (zero cross-shard fanout, the §4.2.3 symmetric-sharding
    goal); same-user adjacency maximizes window-cache hits."""
    order = sorted(
        examples,
        key=lambda e: (shard_of(e.user_id, n_shards), e.user_id, e.request_ts,
                       e.request_id),
    )
    items: List[List[TrainingExample]] = []
    run: List[TrainingExample] = []
    run_shard = None
    for e in order:
        shard = shard_of(e.user_id, n_shards)
        if run and (shard != run_shard or len(run) >= base_batch_size):
            items.append(run)
            run = []
        run_shard = shard
        run.append(e)
    if run:
        items.append(run)
    return _plan(items, n_shards)


def plan_arrival_order(
    examples: Sequence[TrainingExample],
    n_shards: int,
    base_batch_size: int,
) -> AffinityPlan:
    """Baseline plan: arrival order (no clustering) — what a Fat-Row-era
    pipeline does; used as the benchmark control."""
    order = list(examples)
    items = [
        order[i : i + base_batch_size]
        for i in range(0, len(order), base_batch_size)
    ]
    return _plan(items, n_shards)


def _plan(items: List[List[TrainingExample]], n_shards: int) -> AffinityPlan:
    fanouts = []
    amortizable = 0
    for item in items:
        fanouts.append(len({shard_of(e.user_id, n_shards) for e in item}))
        for a, b in zip(item, item[1:]):
            same_window = (
                not a.is_fat
                and not b.is_fat
                and a.user_id == b.user_id
                and a.version is not None
                and b.version is not None
                and (a.version.start_ts, a.version.end_ts)
                == (b.version.start_ts, b.version.end_ts)
            )
            amortizable += int(same_window)
    return AffinityPlan(
        items=items,
        expected_fanout=sum(fanouts) / max(len(fanouts), 1),
        amortizable_pairs=amortizable,
    )
