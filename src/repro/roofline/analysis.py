"""Three-term roofline from compiled dry-run artifacts (TPU v5e targets).

  compute    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
  memory     = HLO_bytes / (chips * 819e9 B/s HBM)
  collective = link_bytes_per_chip / 50e9 B/s ICI per link

``cost_analysis()`` on a compiled SPMD executable reports per-device flops
and bytes; the collective term comes from the HLO parser. The dominant term is
the bottleneck; roofline fraction = model_flops-derived ideal time / dominant.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    link_bytes_per_chip: float
    model_flops_total: float
    collective_counts: Dict[str, int]

    @property
    def t_compute(self) -> float:
        return self.hlo_flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.link_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def model_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO flops — how much compiled compute is useful
        (catches remat/redundancy waste)."""
        total_hlo = self.hlo_flops_per_chip * self.chips
        return self.model_flops_total / max(total_hlo, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Ideal (useful-flops-limited) time / bound time."""
        t_ideal = self.model_flops_total / (self.chips * PEAK_FLOPS)
        return t_ideal / max(self.t_bound, 1e-30)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "hlo_bytes_per_chip": self.hlo_bytes_per_chip,
            "link_bytes_per_chip": self.link_bytes_per_chip,
            "model_flops_total": self.model_flops_total,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops_ratio": self.model_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collective_counts": self.collective_counts,
        }


def from_compiled(arch: str, shape: str, mesh_name: str, chips: int,
                  cost: Optional[Dict[str, float]],
                  link_bytes: float, collective_counts: Dict[str, int],
                  model_flops: float) -> Roofline:
    flops = float(cost.get("flops", 0.0)) if cost else 0.0
    nbytes = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=flops, hlo_bytes_per_chip=nbytes,
        link_bytes_per_chip=link_bytes,
        model_flops_total=model_flops,
        collective_counts=collective_counts,
    )
