"""Offloaded compaction (paper §4.1.2, §4.3).

A daily ETL pipeline rebuilds the *entire lookback window* for every user from
source-of-truth data, producing complete, chronologically ordered sequences cut
into fixed-length stripes per (user_id, feature_group), pre-sorted to match the
store topology, then bulk-loaded as a single-level generation.

Because each cycle regenerates the full window:
  * multi-stripe range scans stay purely sequential (all temporal stripes of a
    user are coalesced into one run);
  * right-to-delete compliance is enforced idempotently (scrub predicates are
    re-applied on every cycle — no retroactive patching);
  * schema evolution (new/deprecated SideInfo traits) is a single pipeline run,
    not a multi-day backfill.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import events as ev
from repro.storage import columnar
from repro.storage.immutable_store import ImmutableUIHStore, Stripe

# source-of-truth reader: (user_id, t_lo, t_hi) -> full-schema EventBatch
SourceFn = Callable[[int, int, int], ev.EventBatch]
# right-to-delete: EventBatch -> bool mask of events to KEEP
ScrubFn = Callable[[ev.EventBatch], np.ndarray]


@dataclasses.dataclass
class CompactionConfig:
    stripe_len: int = 256          # events per stripe (fixed-length subsequences)
    lookback_ms: int = 365 * ev.MS_PER_DAY
    compress: bool = False


@dataclasses.dataclass
class CompactionReport:
    generation: int
    users: int = 0
    events: int = 0
    scrubbed_events: int = 0
    stripes: int = 0
    output_bytes: int = 0
    watermark_ts: int = -1


def make_scrub(
    deleted_items: Iterable[int] = (),
    deleted_creators: Iterable[int] = (),
) -> ScrubFn:
    items = np.asarray(sorted(set(int(i) for i in deleted_items)), dtype=np.int64)
    creators = np.asarray(sorted(set(int(c) for c in deleted_creators)), dtype=np.int64)

    def scrub(batch: ev.EventBatch) -> np.ndarray:
        n = ev.batch_len(batch)
        keep = np.ones(n, dtype=bool)
        if items.size and "item_id" in batch:
            keep &= ~np.isin(batch["item_id"], items)
        if creators.size and "creator_id" in batch:
            keep &= ~np.isin(batch["creator_id"], creators)
        return keep

    return scrub


class CompactionPipeline:
    def __init__(
        self,
        schema: ev.TraitSchema,
        cfg: Optional[CompactionConfig] = None,
    ):
        self.schema = schema
        self.cfg = cfg or CompactionConfig()

    def _stripes_for_group(
        self, history: ev.EventBatch, group: str
    ) -> List[Stripe]:
        traits = self.schema.group_traits(group)
        cols = ev.project_traits(history, traits)
        n = ev.batch_len(cols)
        out: List[Stripe] = []
        for lo in range(0, n, self.cfg.stripe_len):
            hi = min(lo + self.cfg.stripe_len, n)
            piece = ev.slice_batch(cols, lo, hi)
            blob = columnar.encode_stripe(piece, self.schema, self.cfg.compress)
            out.append(
                Stripe(
                    start_ts=int(piece["timestamp"][0]),
                    end_ts=int(piece["timestamp"][-1]),
                    n_events=hi - lo,
                    blob=blob,
                )
            )
        return out

    def run(
        self,
        source: SourceFn,
        user_ids: Sequence[int],
        as_of_ts: int,
        store: ImmutableUIHStore,
        scrub: Optional[ScrubFn] = None,
        generation: Optional[int] = None,
    ) -> CompactionReport:
        """Rebuild the full lookback window as of ``as_of_ts`` and bulk-load it.

        ``as_of_ts`` becomes the immutable watermark: events with
        timestamp <= as_of_ts move to the immutable tier; the mutable tier may
        evict them afterwards (retention coupling, §4.1.1)."""
        gen = store.generation + 1 if generation is None else generation
        report = CompactionReport(generation=gen)
        tables: Dict[Tuple[int, str], List[Stripe]] = {}
        t_lo = max(0, as_of_ts - self.cfg.lookback_ms)
        for uid in user_ids:
            history = source(int(uid), t_lo, as_of_ts)
            n_raw = ev.batch_len(history)
            if n_raw == 0:
                continue
            ev.validate_batch(history)
            if scrub is not None:
                keep = scrub(history)
                history = ev.take_batch(history, np.nonzero(keep)[0])
                report.scrubbed_events += int(n_raw - ev.batch_len(history))
            if ev.batch_len(history) == 0:
                continue
            report.users += 1
            report.events += ev.batch_len(history)
            for group in self.schema.feature_groups:
                stripes = self._stripes_for_group(history, group)
                if stripes:
                    tables[(int(uid), group)] = stripes
                    report.stripes += len(stripes)
                    report.output_bytes += sum(len(s.blob) for s in stripes)
        store.bulk_load(tables, generation=gen)
        report.watermark_ts = as_of_ts
        return report
