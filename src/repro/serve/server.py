"""Snapshot-consistent low-latency retrieval serving (DESIGN.md §14).

``RetrievalServer`` is the inference half of the paper's O2O story: the same
versioned store that training materializes from answers live top-k requests.

Request lifecycle (§14.1):

  1. callers ``submit()`` / ``retrieve()``; the ``RequestCoalescer`` forms
     latency-bounded micro-batches (deadline + max-batch);
  2. a serving worker takes ONE transient ``GenerationLease`` per micro-batch
     — every watermark read, embedding-cache probe and immutable scan in the
     batch resolves the SAME generation, so a request can never straddle a
     compaction flip (the snapshotter's consistency contract, reused verbatim
     including the first-flip retry and the ``StaleGeneration`` remediation
     path of the shared ``Materializer``);
  3. per user: resolve ``end_ts = min(watermark, request_ts)``, read the
     mutable slice ``(end_ts, request_ts]``, and probe the
     ``UserEmbeddingCache`` with the exact ``(generation, freshness)`` tag —
     a hit skips store scan + featurize + user-tower forward entirely;
  4. cache misses build synthetic VLM examples (version metadata pointing at
     the leased generation) and go through ``Materializer.materialize_batch``
     → ``featurize`` → the jitted user tower, padded to a fixed batch shape
     so results are byte-identical regardless of batch composition (which is
     what makes cache-on vs cache-off byte-identical, and keeps one XLA
     compilation per shape);
  5. all embeddings (cached + fresh) are scored against the
     ``CandidateIndex`` in one batched ``top_k``; per-request ``k`` slices
     the shared ``k_max`` result.

The server works unchanged over the monolith and the sharded/replicated
store (anything satisfying ``StoreProtocol``): degraded-mode behavior —
failover, hedged reads, breaker-gated replicas, partial reissues — lives
below the protocol surface, and a batch that still fails (e.g. every replica
of a shard down) fails ONLY its own requests, releases its lease, and the
server keeps serving.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core.materialize import Materializer, StaleGeneration
from repro.core.projection import TenantProjection
from repro.core.versioning import TrainingExample, VersionMetadata
from repro.dpp.featurize import FeatureSpec, featurize
from repro.models import recsys as R
from repro.obs.spans import ItemSpan
from repro.serve.cache import UserEmbeddingCache
from repro.serve.coalescer import PendingRequest, RequestCoalescer
from repro.serve.index import CandidateIndex

# request-latency buckets: serving sits in the 100us..1s range, far below
# the registry's training-step DEFAULT_BUCKETS
SERVE_LATENCY_BUCKETS = (0.0005, 0.001, 0.002, 0.005, 0.01, 0.02,
                         0.05, 0.1, 0.25, 0.5, 1.0, 2.5)


@dataclasses.dataclass
class ServeConfig:
    """Knobs of the serving tier (coalescing, caching, consistency)."""

    max_batch: int = 16          # micro-batch size cap (also the pad shape)
    max_delay_s: float = 0.002   # oldest queued request waits at most this
    n_workers: int = 1           # serving worker threads (share one cache)
    default_k: int = 10          # top-k when the request does not say
    cache_capacity: int = 2048   # user-embedding LRU entries (0 = disabled)
    lookback_ms: int = 365 * ev.MS_PER_DAY   # UIH lookback horizon
    validate_checksum: bool = True           # forwarded to the Materializer
    window_cache_size: int = 256             # Materializer cross-batch LRU
    span_capacity: int = 512     # per-batch serve spans retained
    topk_sample_every: int = 64  # emit a serve_topk_sample event every N
    #                              batches (0 = never); feeds the report CLI
    stale_retries: int = 2       # micro-batch retries on StaleGeneration


@dataclasses.dataclass
class ServeStats:
    requests: int = 0            # requests answered (ok or failed)
    batches: int = 0             # micro-batches processed
    cold_requests: int = 0       # full scan+featurize+encode path
    cached_requests: int = 0     # answered from the user-embedding cache
    failed_requests: int = 0     # requests completed exceptionally
    stale_batch_retries: int = 0 # micro-batches retried after StaleGeneration
    lease_flip_retries: int = 0  # gen<0 lease raced the first compaction
    padded_rows: int = 0         # encode rows spent on fixed-shape padding


@dataclasses.dataclass
class RetrievalResult:
    """One answered request: best-first candidates + provenance tags."""

    user_id: int
    request_ts: int
    item_ids: np.ndarray         # [k] int64
    scores: np.ndarray           # [k] float32
    generation: int              # immutable generation the answer resolved on
    index_version: int           # candidate-index version that scored it
    cached: bool                 # user embedding came from the cache


class RetrievalServer:
    """Coalescing, snapshot-consistent two-tower retrieval server."""

    def __init__(
        self,
        store,
        mutable,
        schema: ev.TraitSchema,
        params,
        model_cfg: R.TwoTowerConfig,
        projection: Optional[TenantProjection] = None,
        feature_spec: Optional[FeatureSpec] = None,
        cfg: Optional[ServeConfig] = None,
        telemetry=None,
        index: Optional[CandidateIndex] = None,
    ):
        self.store = store
        self.mutable = mutable
        self.schema = schema
        self.params = params
        self.model_cfg = model_cfg
        self.cfg = cfg or ServeConfig()
        self.telemetry = telemetry
        self.projection = projection or TenantProjection(
            "serve", seq_len=model_cfg.uih_len, feature_groups=("core",),
            traits_per_group={"core": ("timestamp", "item_id")})
        self.feature_spec = feature_spec or FeatureSpec(
            seq_len=model_cfg.uih_len, uih_traits=("item_id",))
        self.materializer = Materializer(
            store, schema,
            validate_checksum=self.cfg.validate_checksum,
            pin_generations=True,
            window_cache_size=self.cfg.window_cache_size)
        self.index = index or CandidateIndex(model_cfg, telemetry=telemetry)
        if self.index.version == 0:
            self.index.refresh(params)
        self.cache = (UserEmbeddingCache(self.cfg.cache_capacity)
                      if self.cfg.cache_capacity > 0 else None)
        self.coalescer = RequestCoalescer(
            max_batch=self.cfg.max_batch, max_delay_s=self.cfg.max_delay_s)
        self.stats = ServeStats()
        self.spans: deque = deque(maxlen=self.cfg.span_capacity)
        self._user_fn = jax.jit(
            lambda p, uid, ids, mask: R.two_tower_user(
                p, uid, ids, mask, model_cfg))
        self._lock = threading.Lock()   # stats + request-id counter
        self._next_rid = 0
        self._lat_hist = None
        self._stage_ctr = None
        if telemetry is not None:
            self._lat_hist = telemetry.registry.histogram(
                "repro_serve_request_seconds",
                "retrieval request latency, submit to answer",
                buckets=SERVE_LATENCY_BUCKETS).labels()
            self._stage_ctr = telemetry.registry.counter(
                "repro_serve_stage_seconds_total",
                "serving worker seconds by pipeline stage",
                labels=("stage",))
        self._workers = [
            threading.Thread(target=self._worker_loop, daemon=True,
                             name=f"serve-worker-{i}")
            for i in range(self.cfg.n_workers)
        ]
        self._closed = False
        for t in self._workers:
            t.start()

    # -- public API ----------------------------------------------------------
    @classmethod
    def from_sim(cls, sim, params, model_cfg: R.TwoTowerConfig,
                 cfg: Optional[ServeConfig] = None, telemetry=None,
                 **kw) -> "RetrievalServer":
        """Wire a server onto a ``ProductionSim``'s live tiers (monolith or
        sharded — whatever ``sim.immutable`` is)."""
        if cfg is None:
            cfg = ServeConfig(lookback_ms=sim.cfg.lookback_ms)
        return cls(sim.immutable, sim.mutable, sim.schema, params, model_cfg,
                   cfg=cfg, telemetry=telemetry, **kw)

    def submit(self, user_id: int, request_ts: int,
               k: Optional[int] = None) -> PendingRequest:
        if self._closed:
            raise RuntimeError("server is closed")
        return self.coalescer.submit(
            PendingRequest(user_id, k or self.cfg.default_k, request_ts))

    def retrieve(self, user_id: int, request_ts: int,
                 k: Optional[int] = None,
                 timeout: float = 30.0) -> RetrievalResult:
        return self.submit(user_id, request_ts, k).result(timeout)

    def close(self) -> None:
        """Drain queued requests, stop the workers, publish final telemetry.
        Leases are strictly per-micro-batch, so after close the server holds
        none (asserted by tests via ``store.leased_generations()``)."""
        if self._closed:
            return
        self._closed = True
        self.coalescer.close()
        for t in self._workers:
            t.join()
        self.publish_telemetry()

    def publish_telemetry(self) -> None:
        if self.telemetry is None:
            return
        self.telemetry.publish_stats(self.stats, "serve")
        self.telemetry.publish_stats(self.coalescer.stats, "serve_coalesce")
        if self.cache is not None:
            self.telemetry.publish_stats(self.cache.stats, "serve_embed_cache")
        self.telemetry.publish_stats(self.index.stats, "serve_index")
        self.telemetry.publish_stats(self.materializer.stats, "serve_mat")

    # -- worker --------------------------------------------------------------
    def _worker_loop(self) -> None:
        while True:
            batch, flush = self.coalescer.next_batch()
            if batch is None:
                return
            try:
                self._process_batch(batch, flush)
            except BaseException as e:   # noqa: BLE001 — server must survive
                with self._lock:
                    self.stats.failed_requests += sum(
                        0 if p.done() else 1 for p in batch)
                    self.stats.requests += len(batch)
                    self.stats.batches += 1
                for p in batch:
                    p._fail(e)

    def _process_batch(self, batch: List[PendingRequest], flush: str) -> None:
        """One micro-batch, retried whole on ``StaleGeneration`` (the lease
        makes that unreachable in steady state — the retry is the remediation
        backstop the snapshotter contract requires)."""
        attempt = 0
        while True:
            try:
                self._serve_batch(batch, flush)
                return
            except StaleGeneration:
                attempt += 1
                with self._lock:
                    self.stats.stale_batch_retries += 1
                if attempt > self.cfg.stale_retries:
                    raise

    def _acquire_consistent_lease(self):
        """The snapshotter's first-flip contract: a lease on generation -1
        pins nothing, so if the FIRST compaction published while we grabbed
        it, re-acquire against the now-live generation."""
        while True:
            lease = self.store.acquire_lease()
            if lease.generation < 0 and self.store.generation >= 0:
                lease.release()
                with self._lock:
                    self.stats.lease_flip_retries += 1
                continue
            return lease

    def _serve_batch(self, batch: List[PendingRequest], flush: str) -> None:
        cfg = self.cfg
        t_start = time.monotonic()
        n = len(batch)
        embs: List[Optional[np.ndarray]] = [None] * n
        cold_idx: List[int] = []
        cold_examples: List[TrainingExample] = []
        cold_fresh: Dict[int, tuple] = {}

        lease = self._acquire_consistent_lease()
        gen = lease.generation
        try:
            # probe: per user, resolve the two-tier boundary under the lease
            # and try the embedding cache with the exact state tag
            for i, p in enumerate(batch):
                start_ts = max(0, p.request_ts - cfg.lookback_ms)
                wm = self.store.watermark(p.user_id, generation=gen)
                end_ts = min(wm, p.request_ts)
                # O(1) freshness tag: (request window, mutable write-state
                # version) — a hit skips even the mutable merged-view read
                fresh = (start_ts, end_ts, p.request_ts,
                         self.mutable.version(p.user_id))
                if self.cache is not None:
                    hit, reason = self.cache.get(p.user_id, gen, fresh)
                    if hit is not None:
                        embs[i] = hit
                        continue
                    if reason != "miss" and self.telemetry is not None:
                        self.telemetry.events.emit(
                            "serve_cache_invalidation", user=p.user_id,
                            reason=reason, generation=gen)
                mut = self.mutable.read(
                    p.user_id, max(end_ts, start_ts - 1), p.request_ts)
                cold_idx.append(i)
                cold_fresh[i] = fresh
                cold_examples.append(TrainingExample(
                    request_id=self._alloc_rid(),
                    user_id=p.user_id,
                    request_ts=p.request_ts,
                    label_ts=p.request_ts,
                    candidate={},
                    labels={},
                    mutable_uih=mut,
                    version=VersionMetadata(
                        start_ts=start_ts, end_ts=end_ts, seq_len=0,
                        checksum=0, generation=gen),
                ))

            # cold path: scan -> featurize -> encode, all under the lease so
            # the pinned generation cannot be GC'd mid-materialization
            t_probe = time.monotonic()
            t_scan = t_feat = t_encode = t_probe
            if cold_idx:
                uihs = self.materializer.materialize_batch(
                    cold_examples, self.projection)
                t_scan = time.monotonic()
                feats = featurize(cold_examples, uihs, self.feature_spec)
                pad_to = max(cfg.max_batch, len(cold_idx))
                uid = _pad_rows(feats["user_id"], pad_to)
                ids = _pad_rows(feats["uih_item_id"], pad_to)
                mask = _pad_rows(feats["uih_mask"], pad_to)
                t_feat = time.monotonic()
                fresh_embs = np.asarray(
                    self._user_fn(self.params, uid, ids, mask))[:len(cold_idx)]
                t_encode = time.monotonic()
                for j, i in enumerate(cold_idx):
                    embs[i] = fresh_embs[j]
                    if self.cache is not None:
                        self.cache.put(batch[i].user_id, gen,
                                       cold_fresh[i], fresh_embs[j])
                with self._lock:
                    self.stats.padded_rows += pad_to - len(cold_idx)
        finally:
            lease.release()

        # score: one batched top_k over cached + fresh embeddings (the lease
        # is no longer needed — the store is out of the picture)
        k_max = max(p.k for p in batch)
        pad_to = max(cfg.max_batch, n)
        user_mat = _pad_rows(np.stack(embs, axis=0), pad_to)
        item_ids, scores = self.index.top_k(user_mat, k_max)
        t_score = time.monotonic()
        index_version = self.index.version

        now = time.monotonic()
        for i, p in enumerate(batch):
            p._resolve(RetrievalResult(
                user_id=p.user_id,
                request_ts=p.request_ts,
                item_ids=item_ids[i, :p.k],
                scores=scores[i, :p.k],
                generation=gen,
                index_version=index_version,
                cached=i not in cold_fresh,
            ))
            if self._lat_hist is not None:
                self._lat_hist.observe(now - p.enqueue_t)

        n_cold = len(cold_idx)
        with self._lock:
            self.stats.requests += n
            self.stats.batches += 1
            self.stats.cold_requests += n_cold
            self.stats.cached_requests += n - n_cold
            batch_seq = self.stats.batches
        self._record_span(batch_seq, flush, gen, n, n_cold, t_start,
                          t_probe, t_scan, t_feat, t_encode, t_score)
        if (self.telemetry is not None and cfg.topk_sample_every
                and batch_seq % cfg.topk_sample_every == 1):
            p = batch[0]
            self.telemetry.events.emit(
                "serve_topk_sample", user=p.user_id, k=p.k,
                generation=gen, index_version=index_version,
                items=[int(x) for x in item_ids[0, :p.k]],
                scores=[round(float(s), 5) for s in scores[0, :p.k]])

    def _record_span(self, seq, flush, gen, size, cold, t_start, t_probe,
                     t_scan, t_feat, t_encode, t_score) -> None:
        sp = ItemSpan(seq=seq, t_mint=t_start)
        sp.stage("scan", t_start, t_scan)       # lease + probes + materialize
        sp.stage("featurize", t_scan, t_feat)
        sp.stage("encode", t_feat, t_encode)
        sp.stage("score", t_encode, t_score)
        sp.meta.update(flush=flush, generation=gen, size=size, cold=cold)
        self.spans.append(sp.to_dict())
        if self._stage_ctr is not None:
            for stage in ("scan", "featurize", "encode", "score"):
                self._stage_ctr.labels(stage=stage).inc(sp.stage_s(stage))

    def _alloc_rid(self) -> int:
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            return rid


def _pad_rows(arr: np.ndarray, rows: int) -> np.ndarray:
    """Zero-pad axis 0 to ``rows`` (row-independent ops downstream make the
    padded rows inert — they exist to keep one XLA compile per shape and to
    make per-row results independent of batch composition)."""
    if arr.shape[0] >= rows:
        return arr
    pad = np.zeros((rows - arr.shape[0],) + arr.shape[1:], arr.dtype)
    return np.concatenate([arr, pad], axis=0)
