"""Fault-tolerant data plane (§10): deterministic chaos + exactly-once resume.

Covers the PR's acceptance spine:
  * seeded fault matrix — for each injectable fault kind × {batch, streaming},
    the feed completes with BYTE-IDENTICAL batches to the fault-free run
    (ordered placement + pool self-healing), the trained-example multiset is
    exact, ``consistency.audit`` stays clean, and zero generation leases leak;
  * self-healing — >= 2 workers crashed mid-run are requeued + respawned
    (``worker_restarts``/``items_requeued``/``lease_recoveries`` counters);
  * kill-and-resume — ``Trainer.fit`` killed at an arbitrary step, restored
    via ``CheckpointManager`` (model state) + ``open_feed(resume_from=
    feed_state)`` (data cursor), trains the exact remaining example multiset
    in both batch and streaming modes (streaming: across the backfill flip);
  * ``plan_affine`` properties (hypothesis / fallback sweep): single-shard
    items, exact partition of the input, permutation invariance;
  * retry exhaustion: a poison item is abandoned through ``on_abandon``
    (streaming drop semantics) or surfaces as an error (batch).
"""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - environment-dependent
    from _hypothesis_fallback import given, settings, strategies as st

from conftest import make_sim, refs_by_id
from repro.core import events as ev
from repro.core.consistency import audit
from repro.core.projection import TenantProjection
from repro.core.versioning import TrainingExample
from repro.data import DatasetSpec, SimSource, StreamSource, WarehouseSource, open_feed
from repro.dpp.affinity import plan_affine
from repro.dpp.featurize import FeatureSpec
from repro.storage.sharding import shard_of
from repro.testing import (
    FaultPlan,
    FaultSpec,
    InjectedIOError,
    WorkerCrash,
    wrap_sim,
)

MS_PER_HOUR = 3_600_000

TENANT = TenantProjection(
    "t", 16, ("core",),
    traits_per_group={"core": ("timestamp", "item_id", "action_type")})
FEATURES = FeatureSpec(seq_len=16, uih_traits=("item_id", "action_type"))


def _spec(source, **kw):
    kw.setdefault("batch_size", 8)
    kw.setdefault("base_batch_size", 4)
    kw.setdefault("n_workers", 2)
    kw.setdefault("prefetch_depth", 0)
    # no cross-batch window cache: every work item then issues at least one
    # store scan, so the matrix's scan-tick fault schedule is always reached
    kw.setdefault("window_cache_size", 0)
    return DatasetSpec(tenant=TENANT, source=source, features=FEATURES, **kw)


def _drain(feed):
    out = list(feed)
    feed.join()
    return out


def _row_keys(batches):
    keys = []
    for b in batches:
        for i in range(len(b["user_id"])):
            keys.append((int(b["user_id"][i]), int(b["request_ts"][i]),
                         int(b["cand_item_id"][i])))
    return sorted(keys)


def _example_keys(examples):
    return sorted((e.user_id, e.request_ts, e.candidate["item_id"])
                  for e in examples)


def _assert_batches_equal(want, got):
    assert len(want) == len(got)
    for k_batch, (x, y) in enumerate(zip(want, got)):
        assert x.keys() == y.keys()
        for k in x:
            np.testing.assert_array_equal(x[k], y[k],
                                          err_msg=f"batch {k_batch} key {k}")


def _audit_clean(sim, pin=False):
    mat = sim.materializer(validate_checksum=True, pin_generations=pin)
    report = audit(sim.examples, sim.references, mat, sim.schema, TENANT)
    assert report.clean, dataclasses.asdict(report)
    assert report.examples == len(sim.examples)


# ---------------------------------------------------------------------------
# seeded fault matrix: batch mode
# ---------------------------------------------------------------------------

BATCH_FAULTS = {
    "worker_crash": [FaultSpec("worker_crash", 1), FaultSpec("worker_crash", 3)],
    "scan_ioerror": [FaultSpec("scan_ioerror", 0), FaultSpec("scan_ioerror", 4)],
    "decode_corruption": [FaultSpec("decode_corruption", 2)],
    "compaction_during_scan": [FaultSpec("compaction_during_scan", 1),
                               FaultSpec("compaction_during_scan", 3)],
    "node_unavailable": [FaultSpec("node_unavailable", 1),
                         FaultSpec("node_unavailable", 4)],
    "node_flap": [FaultSpec("node_flap", 1, node=1, duration=2),
                  FaultSpec("node_flap", 4, node=3, duration=2)],
    "node_slow": [FaultSpec("node_slow", 2, node=0, duration=3, factor=6.0)],
}

# fault kinds that need the disaggregated tier: (n_store_nodes, replication).
# node_unavailable raises at the wrapper (the retry path heals it — r=1 shows
# that path is still exercised); flap/slow flip REAL node health, so they run
# replicated and the store's own failover is what absorbs them
NODE_KINDS = {"node_unavailable": (4, 1), "node_flap": (4, 2),
              "node_slow": (4, 2)}
# kinds that surface as a dead worker healed by the DPP pool
HEALED_KINDS = ("worker_crash", "scan_ioerror", "decode_corruption",
                "node_unavailable")


@pytest.mark.parametrize("kind", sorted(BATCH_FAULTS))
def test_batch_fault_matrix_byte_identical_and_audit_clean(kind):
    # node-fault kinds only make sense on the disaggregated tier: run them
    # on a 4-node ShardedUIHStore (same scenario otherwise)
    nodes, repl = NODE_KINDS.get(kind, (0, 1))
    sim = make_sim(users=6, days=2, seed=5, nodes=nodes, replication=repl)
    spec = _spec(WarehouseSource(), consistency="audit")
    clean = _drain(open_feed(spec, sim))
    assert clean and _row_keys(clean) == _example_keys(sim.examples)

    plan = FaultPlan(
        BATCH_FAULTS[kind],
        on_compact=lambda: sim.run_compaction(sim.compaction_watermark,
                                              evict=False))
    fsim = wrap_sim(sim, plan)
    feed = open_feed(spec, fsim)
    chaos = _drain(feed)
    assert plan.n_fired == len(BATCH_FAULTS[kind])   # every fault really fired
    fsim.immutable.settle_node_state()   # a flap/slow the run outlived
    _assert_batches_equal(clean, chaos)
    st = feed.stats()
    if kind in HEALED_KINDS:
        assert st.workers.worker_restarts >= len(BATCH_FAULTS[kind])
        assert st.workers.items_requeued >= len(BATCH_FAULTS[kind])
    if kind == "node_flap":   # r=2: replica failover absorbed the outage
        assert sim.immutable.stats.failovers >= 1
    if kind == "node_slow":   # slowness is never an error
        assert sim.immutable.stats.degraded_scans == 0
    if kind in NODE_KINDS:    # zero leaked leases after the outage
        assert sim.immutable.leased_generations() == {}
    _audit_clean(sim)


# ---------------------------------------------------------------------------
# seeded fault matrix: streaming mode (same-seed twin sims: the run consumes
# the stream, so clean and chaos runs each get their own identical replica)
# ---------------------------------------------------------------------------

STREAM_FAULTS = dict(BATCH_FAULTS)
STREAM_FAULTS["stream_disconnect"] = [FaultSpec("stream_disconnect", 1),
                                      FaultSpec("stream_disconnect", 7)]


def _stream_sim(seed=9, nodes=0, replication=1):
    sim = make_sim(users=6, days=2, seed=seed, pin=True, nodes=nodes,
                   replication=replication)
    sim.stream.close()   # sealed backlog: the feed drains it and ends
    return sim


@pytest.mark.parametrize("kind", sorted(STREAM_FAULTS))
def test_streaming_fault_matrix_byte_identical_and_audit_clean(kind):
    nodes, repl = NODE_KINDS.get(kind, (0, 1))
    spec = _spec(StreamSource(), consistency="audit", generations="pinned")
    sim_clean = _stream_sim(nodes=nodes, replication=repl)
    clean = _drain(open_feed(spec, sim_clean))
    assert clean and _row_keys(clean) == _example_keys(sim_clean.examples)

    sim = _stream_sim(nodes=nodes, replication=repl)
    plan = FaultPlan(
        STREAM_FAULTS[kind],
        on_compact=lambda: sim.run_compaction(sim.compaction_watermark,
                                              evict=False))
    fsim = wrap_sim(sim, plan)
    feed = open_feed(spec, fsim)
    chaos = _drain(feed)
    assert plan.n_fired == len(STREAM_FAULTS[kind])
    fsim.immutable.settle_node_state()
    _assert_batches_equal(clean, chaos)
    # zero leaked generation leases after recovery — pinned streaming runs
    # hold leases THROUGH node faults, so this covers the fan-in path too
    assert sim.stream.pending_leases() == 0
    assert sim.immutable.leased_generations() == {}
    if kind == "stream_disconnect":
        assert feed.session.source.stats.reconnects == 2
    if kind == "node_flap":
        assert sim.immutable.stats.failovers >= 1
    _audit_clean(sim, pin=True)


def test_self_healing_two_worker_crashes_acceptance():
    """Acceptance: a seeded FaultPlan crashing >= 2 workers mid-run — the feed
    completes byte-identical to the fault-free run, recovery counters surface
    the healing, and no GenerationLease leaks."""
    spec = _spec(StreamSource(), consistency="audit", generations="pinned")
    clean = _drain(open_feed(spec, _stream_sim()))

    sim = _stream_sim()
    plan = FaultPlan([FaultSpec("worker_crash", 1), FaultSpec("worker_crash", 3),
                      FaultSpec("worker_crash", 5)])
    feed = open_feed(spec, wrap_sim(sim, plan))
    chaos = _drain(feed)
    assert plan.n_fired >= 2
    _assert_batches_equal(clean, chaos)
    st = feed.stats()
    assert st.workers.worker_restarts >= 2
    assert st.workers.items_requeued >= 2
    assert sim.stream.pending_leases() == 0
    assert sim.immutable.leased_generations() == {}


COMBINED_NODE_FAULTS = [
    FaultSpec("node_flap", 1, node=1, duration=2),
    FaultSpec("node_unavailable", 2),
    FaultSpec("node_slow", 3, node=0, duration=2, factor=5.0),
    FaultSpec("node_flap", 4, node=3, duration=2),
]


def test_combined_node_faults_batch_acceptance_r2():
    """The PR's chaos acceptance: a 4-node r=2 tier hit by node loss, flap
    AND slowness in one run — training completes with byte-identical batches,
    zero abandoned rows, zero leaked leases, and the failover counters show
    the replica path (not luck) absorbed the faults."""
    sim = make_sim(users=6, days=2, seed=5, nodes=4, replication=2)
    spec = _spec(WarehouseSource(), consistency="audit")
    clean = _drain(open_feed(spec, sim))
    assert clean

    plan = FaultPlan(list(COMBINED_NODE_FAULTS))
    fsim = wrap_sim(sim, plan)
    feed = open_feed(spec, fsim)
    chaos = _drain(feed)
    assert plan.n_fired == len(COMBINED_NODE_FAULTS)
    fsim.immutable.settle_node_state()
    _assert_batches_equal(clean, chaos)
    s = sim.immutable.stats
    assert s.failovers >= 1
    assert sim.immutable.leased_generations() == {}
    ns = sim.immutable.node_stats()
    assert not any(ns.down) and not any(ns.pending_replays)
    _audit_clean(sim)


def test_combined_node_faults_streaming_acceptance_r2():
    """Same combined scenario, pinned streaming: generation leases are held
    THROUGH the node faults (fan-in across a dead node), nothing is dropped,
    nothing leaks, and the replayed flap loads leave every node whole."""
    spec = _spec(StreamSource(), consistency="audit", generations="pinned")
    clean = _drain(open_feed(spec, _stream_sim(nodes=4, replication=2)))
    assert clean

    sim = _stream_sim(nodes=4, replication=2)
    plan = FaultPlan(list(COMBINED_NODE_FAULTS))
    fsim = wrap_sim(sim, plan)
    feed = open_feed(spec, fsim)
    chaos = _drain(feed)
    assert plan.n_fired == len(COMBINED_NODE_FAULTS)
    fsim.immutable.settle_node_state()
    _assert_batches_equal(clean, chaos)
    assert feed.session.abandoned == 0            # zero abandoned rows
    assert sim.stream.pending_leases() == 0       # zero leaked leases
    assert sim.immutable.leased_generations() == {}
    assert sim.immutable.stats.failovers >= 1
    _audit_clean(sim, pin=True)


def test_unreplicated_long_outage_degrades_loudly_and_recovers():
    """r=1 contract: with no replica to fail over to, a node outage that
    outlives the retry budget ABANDONS the affected items (surfaced via
    ``session.abandoned`` + ``degraded_scans`` — never a silent drop), the
    rest of the stream trains, and recovery leaves zero leaked leases."""
    sim = _stream_sim(seed=9, nodes=4, replication=1)
    victim_node = sim.immutable._node_of(sim.examples[0].user_id)
    # the flap outlives the whole run: restores settle post-run
    plan = FaultPlan([FaultSpec("node_flap", 0, node=victim_node,
                                duration=10_000)])
    spec = _spec(StreamSource(), generations="pinned", max_item_retries=1)
    fsim = wrap_sim(sim, plan)
    feed = open_feed(spec, fsim)
    got = _drain(feed)
    assert plan.n_fired == 1
    abandoned = feed.session.abandoned
    assert abandoned > 0                          # loud, not silent
    rows = sum(len(b["user_id"]) for b in got)
    assert rows == len(sim.examples) - abandoned  # survivors all trained
    assert sim.immutable.stats.degraded_scans >= 1
    assert feed.stats().workers.lease_recoveries >= abandoned
    assert fsim.immutable.settle_node_state() == 1   # node comes back
    assert sim.stream.pending_leases() == 0
    assert sim.immutable.leased_generations() == {}
    ns = sim.immutable.node_stats()
    assert not any(ns.down) and not any(ns.pending_replays)


def test_seeded_fault_plan_reproducible():
    a = FaultPlan.seeded(7, {"worker_crash": 0.2, "scan_ioerror": 0.1}, 50)
    b = FaultPlan.seeded(7, {"worker_crash": 0.2, "scan_ioerror": 0.1}, 50)
    ticks = lambda p: sorted((f.kind, f.at) for k in p._ticks
                             for f in [FaultSpec(k, t) for t in p._ticks[k]])
    assert ticks(a) == ticks(b)
    assert any(a._ticks[k] for k in a._ticks)   # rate 0.1-0.2 over 50: fires


# ---------------------------------------------------------------------------
# retry exhaustion: poison items
# ---------------------------------------------------------------------------

def test_poison_item_batch_mode_surfaces_error():
    """An item that fails EVERY retry must kill a batch feed (silently
    dropping training data is worse), after max_item_retries attempts."""
    sim = make_sim(users=4, days=1, seed=2, capture_reference=False)
    # a fault at every scan tick: the first item can never succeed
    plan = FaultPlan([FaultSpec("scan_ioerror", t) for t in range(64)])
    spec = _spec(SimSource(), max_item_retries=2, n_workers=1)
    feed = open_feed(spec, wrap_sim(sim, plan))
    _drain_ignore = [b for b in feed]  # noqa: F841  (may be empty)
    with pytest.raises(RuntimeError, match="worker"):
        feed.join()          # wraps the final InjectedIOError as its cause
    st = feed.stats()
    assert st.workers.items_requeued >= spec.max_item_retries


def test_poison_item_streaming_abandons_and_releases_leases():
    """Streaming drop semantics: a poison item is abandoned after its retries,
    its examples' leases released (lease_recoveries), and the rest of the
    stream still trains."""
    sim = _stream_sim(seed=4)
    first_mb = 4
    plan = FaultPlan([FaultSpec("worker_crash", t) for t in range(3)])
    spec = _spec(StreamSource(micro_batch_examples=first_mb),
                 generations="pinned", max_item_retries=2, n_workers=1)
    feed = open_feed(spec, wrap_sim(sim, plan))
    got = _drain(feed)
    rows = sum(len(b["user_id"]) for b in got)
    abandoned = feed.session.abandoned
    assert abandoned == first_mb                  # exactly one item dropped
    assert rows == len(sim.examples) - abandoned  # the rest trained
    st = feed.stats()
    assert st.workers.lease_recoveries == first_mb
    assert sim.stream.pending_leases() == 0       # crash recovery released them
    assert sim.immutable.leased_generations() == {}


def test_kill_and_resume_with_abandoned_item_before_the_kill():
    """Regression: the streaming resume cursor is measured in COORDINATOR
    rows, so rows dropped by protocol (here: an abandoned poison item) before
    the kill must not shift the skip prefix — later trained rows would be
    retrained and the dropped rows resurrected. Dropped rows stay dropped;
    everything else trains exactly once."""
    sim = _stream_sim(seed=12)
    first_mb = 4
    plan = FaultPlan([FaultSpec("worker_crash", t) for t in range(3)])
    spec = _spec(StreamSource(micro_batch_examples=first_mb),
                 generations="pinned", max_item_retries=2, n_workers=1)
    feed = open_feed(spec, wrap_sim(sim, plan))
    trained = []
    for _ in range(2):                       # train past the abandoned item
        b = feed.get(timeout=20.0)
        assert b is not None
        trained.append(b)
        feed.record_train_step(0.001)
    assert feed.session.abandoned == first_mb
    state = feed.checkpoint()
    # the skip prefix covers the abandoned rows: 2 batches of 8 placed rows
    # plus the 4 dropped coordinator rows interleaved before them
    assert state["stream"]["filters"][-1]["skip_rows"] == 16 + first_mb
    feed.close(timeout=30.0)

    feed2 = open_feed(spec, sim, resume_from=state)   # fault-free resume
    rest = _drain(feed2)
    got = _row_keys(trained) + _row_keys(rest)
    want = _example_keys(sim.examples)
    assert len(got) == len(want) - first_mb   # dropped rows stay dropped...
    assert len(set(got)) == len(got)          # ...and nothing trained twice
    assert set(got) <= set(want)
    assert sim.stream.pending_leases() == 0


# ---------------------------------------------------------------------------
# acceptance: kill-and-resume (Trainer + CheckpointManager + open_feed)
# ---------------------------------------------------------------------------

def _loss_and_params():
    import jax.numpy as jnp

    def loss_fn(params, b):
        score = jnp.sum(b["uih_item_id"] * params["w"], axis=1)
        return jnp.mean((score - b["label_click"]) ** 2)

    return loss_fn, {"w": jnp.zeros((16,), jnp.float32)}


def _fit_recording(trainer, feed_args, max_steps=None):
    """Run Trainer.fit over an open_feed(*feed_args) feed, recording every
    DELIVERED batch via prep_fn. With prefetch_depth=0 the trainer trains each
    batch immediately after pulling it, so the recording equals the trained
    sequence."""
    recorded = []
    feed = open_feed(*feed_args[:-1], prep_fn=lambda b: (recorded.append(b), b)[1],
                     **feed_args[-1])
    trainer.fit(feed, max_steps=max_steps)
    return feed, recorded


def test_kill_and_resume_batch_exactly_once(tmp_path):
    from repro.train.train_loop import Trainer, TrainerConfig

    sim = make_sim(users=6, days=2, seed=6, capture_reference=False)
    spec = _spec(WarehouseSource(), reshuffle_seed=3)
    uninterrupted = _drain(open_feed(spec, sim))
    total_rows = sum(len(b["user_id"]) for b in uninterrupted)
    n_batches = len(uninterrupted)
    assert n_batches >= 4

    loss_fn, params = _loss_and_params()
    cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=2, log_every=10**6)
    t1 = Trainer(loss_fn, params, cfg)
    kill_at = n_batches - 2            # an arbitrary mid-run step
    feed1, run1 = _fit_recording(t1, (spec, sim, {}), max_steps=kill_at)
    assert t1.step == kill_at
    feed1.close(timeout=30.0)          # "kill": prefetched work is discarded

    # restart: model from CheckpointManager, data cursor from the sidecar
    t2 = Trainer(loss_fn, params, cfg)
    assert t2.try_resume()
    restored_step = t2.step
    assert 0 < restored_step <= kill_at
    feed_state = t2.ckpt.feed_state(restored_step)
    assert feed_state is not None
    assert feed_state["trained_batches"] == restored_step
    assert "warehouse" in feed_state    # hour + intra-hour offset cursor
    feed2, run2 = _fit_recording(t2, (spec, sim, {"resume_from": feed_state}))
    feed2.close(timeout=30.0)

    # exactly-once: steps up to the restored checkpoint + the resumed run are
    # byte-identical to the uninterrupted run — nothing trained twice (beyond
    # the discarded post-checkpoint steps a kill always loses), none skipped
    replay = run1[:restored_step] + run2
    _assert_batches_equal(uninterrupted, replay)
    assert sum(len(b["user_id"]) for b in replay) == total_rows


def test_kill_and_resume_streaming_exactly_once_across_flip(tmp_path):
    """Streaming acceptance: kill AFTER the backfill->live flip; the resumed
    feed re-replays the (now longer) warehouse sweep with the checkpoint's
    ReplayFilter chain — replay prefix skipped, live-trained id interval
    dropped — and trains exactly the remaining multiset."""
    from repro.train.train_loop import Trainer, TrainerConfig

    sim = make_sim(users=6, days=2, seed=8, pin=True)   # days 0-1 sealed
    h1 = max(e.request_ts // MS_PER_HOUR for e in sim.examples)
    sim.run_day(2, capture_reference=True)   # day-2: live leg + warehouse
    sim.stream.close()
    day01_rows = sum(1 for e in sim.examples
                     if e.request_ts // MS_PER_HOUR <= h1)

    # run 1 replays only the sealed hours; day-2 examples arrive LIVE
    spec1 = _spec(StreamSource(backfill_end_hour=h1), generations="pinned",
                  reshuffle_seed=3)
    loss_fn, params = _loss_and_params()
    cfg = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=2, log_every=10**6)
    t1 = Trainer(loss_fn, params, cfg)
    kill_at = day01_rows // spec1.batch_size + 2   # crosses into the live phase
    feed1, run1 = _fit_recording(t1, (spec1, sim, {}), max_steps=kill_at)
    assert t1.step == kill_at
    feed1.close(timeout=30.0)

    t2 = Trainer(loss_fn, params, cfg)
    assert t2.try_resume()
    feed_state = t2.ckpt.feed_state(t2.step)
    assert feed_state is not None
    filt = feed_state["stream"]["filters"][-1]
    assert filt["skip_rows"] == day01_rows        # replay prefix fully trained
    assert filt["drop_hi"] > filt["drop_lo"] >= 0  # live interval is non-empty

    # restart replays the FULL warehouse (head moved past h1): consumed-but-
    # untrained live rows are recovered from the warehouse leg
    spec2 = _spec(StreamSource(), generations="pinned", reshuffle_seed=3)
    feed2, run2 = _fit_recording(t2, (spec2, sim,
                                      {"resume_from": feed_state}))
    feed2.close(timeout=30.0)

    trained = _row_keys(run1[:t2.step]) + _row_keys(run2)
    assert sorted(trained) == _example_keys(sim.examples)   # exactly once
    assert sim.stream.pending_leases() == 0
    mat = sim.materializer(validate_checksum=True, pin_generations=True)
    report = audit(sim.examples, sim.references, mat, sim.schema, TENANT)
    assert report.clean


# ---------------------------------------------------------------------------
# satellite: plan_affine properties (hypothesis / fallback sweep)
# ---------------------------------------------------------------------------

def _mk_examples(n, n_users, seed):
    rng = np.random.default_rng(seed)
    return [
        TrainingExample(
            request_id=i,
            user_id=int(rng.integers(0, n_users)),
            request_ts=int(rng.integers(0, 10_000)),
            label_ts=0, candidate={"item_id": 0}, labels={"click": 0.0},
        )
        for i in range(n)
    ]


@settings(max_examples=25, deadline=None)
@given(n=st.integers(min_value=0, max_value=60),
       n_users=st.integers(min_value=1, max_value=12),
       n_shards=st.sampled_from([1, 2, 4, 8]),
       base=st.integers(min_value=1, max_value=9),
       seed=st.integers(min_value=0, max_value=10_000))
def test_plan_affine_properties(n, n_users, n_shards, base, seed):
    examples = _mk_examples(n, n_users, seed)
    plan = plan_affine(examples, n_shards, base)

    # 1) every item targets exactly ONE shard (symmetric sharding, §4.2.3)
    for item in plan.items:
        assert item
        assert len({shard_of(e.user_id, n_shards) for e in item}) == 1
        assert len(item) <= base
    if plan.items:
        assert plan.expected_fanout == 1.0

    # 2) the items partition the input: every example exactly once
    got = sorted(e.request_id for item in plan.items for e in item)
    assert got == sorted(e.request_id for e in examples)

    # 3) invariant under input permutation (total-order sort key)
    rng = np.random.default_rng(seed + 1)
    shuffled = [examples[i] for i in rng.permutation(len(examples))]
    plan2 = plan_affine(shuffled, n_shards, base)
    assert [[e.request_id for e in item] for item in plan.items] == \
           [[e.request_id for e in item] for item in plan2.items]
