"""Public jit'd wrapper: pads ragged shapes to block multiples, picks
interpret mode automatically off-TPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.delta_decode.delta_decode import delta_decode_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def delta_decode(deltas: jax.Array, bases: jax.Array,
                 block_b: int = 8, block_n: int = 128) -> jax.Array:
    """Batched stripe timestamp decode; auto-pads to VMEM block multiples."""
    b, n = deltas.shape
    bb = min(block_b, max(1, b))
    pb = (bb - b % bb) % bb
    pn = (block_n - n % block_n) % block_n
    d = jnp.pad(deltas.astype(jnp.int32), ((0, pb), (0, pn)))
    bs = jnp.pad(bases.astype(jnp.int32), (0, pb))
    out = delta_decode_kernel(d, bs, block_b=bb, block_n=block_n,
                              interpret=not _on_tpu())
    return out[:b, :n]
