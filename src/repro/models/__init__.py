"""Pure-JAX model zoo: LM transformers (GQA / MLA / MoE), MeshGraphNet,
and recsys models (two-tower, DCN-v2, DIEN, BERT4Rec, DLRM-UIH)."""
