"""Elastic DPP scaling + straggler mitigation (paper §4.2.1; fault tolerance).

The controller watches job-level GPU-starvation % (trainer idle) and worker
waste % (CPU idle) and adjusts the provisioned worker count so training stays
compute-bound. ``DPPWorkerPool`` runs N featurizing workers over planned work
items straight into the trainer's slot-based rebatching client, resizing live
on the controller's decisions. ``StragglerAwarePool`` re-dispatches work items
whose worker exceeded the straggler deadline (speculative execution), and
survives worker crashes.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence


@dataclasses.dataclass
class ElasticConfig:
    min_workers: int = 1
    max_workers: int = 32
    target_starvation_pct: float = 2.0   # scale up above this
    target_waste_pct: float = 60.0       # scale down above this
    step: int = 1


class ElasticController:
    """Pure decision logic (separated from the pool so it is unit-testable)."""

    def __init__(self, cfg: ElasticConfig):
        self.cfg = cfg
        self.decisions: List[int] = []

    def decide(self, workers: int, starvation_pct: float, waste_pct: float) -> int:
        new = workers
        if starvation_pct > self.cfg.target_starvation_pct:
            new = min(self.cfg.max_workers, workers + self.cfg.step)
        elif waste_pct > self.cfg.target_waste_pct and starvation_pct == 0.0:
            new = max(self.cfg.min_workers, workers - self.cfg.step)
        self.decisions.append(new)
        return new


@dataclasses.dataclass
class PoolStats:
    completed: int = 0
    speculative_retries: int = 0
    worker_failures: int = 0


class DPPWorkerPool:
    """N DPP workers draining planned work items into a rebatching client.

    Each thread owns a private ``DPPWorker`` (materializers are not shared
    across threads — their window caches and IO accounting are thread-local by
    design), pulls work items (example lists, e.g. ``plan_affine(...).items``)
    from a shared queue, and ``put``s the featurized base batch into the slot
    buffer of the trainer's ``RebatchingClient``.

    Elasticity: a monitor thread periodically feeds the job-level signals —
    trainer ``starvation_pct`` from the client, mean worker ``waste_pct`` —
    to an ``ElasticController`` and applies its decision: growth starts new
    worker threads immediately; shrink is cooperative (threads with index
    beyond the target retire before their next pull). Worker exceptions are
    captured and re-raised from ``join``/``run`` — never swallowed.
    """

    def __init__(
        self,
        worker_factory: Callable[[], "object"],
        client,
        n_workers: int = 2,
        controller: Optional[ElasticController] = None,
        control_interval_s: float = 0.25,
        close_client: bool = True,
        jagged: bool = True,
    ):
        self.worker_factory = worker_factory
        self.client = client
        self.controller = controller
        self.control_interval_s = control_interval_s
        self.close_client = close_client
        # fused path: workers emit arena+offsets base batches and the client
        # scatters them straight into slots (falls back to the dense put when
        # either side predates the jagged API)
        self.jagged = (jagged and hasattr(client, "put_jagged"))
        self._items: "queue.Queue" = queue.Queue()
        self._n_initial = n_workers
        self._lock = threading.Lock()
        self._threads: List[threading.Thread] = []
        self._workers: List[object] = []
        self._errors: List[BaseException] = []
        self._live = 0      # threads spawned and not yet exited
        self._retire = 0    # pending cooperative-shrink tokens
        self._done = threading.Event()
        # set once no further items will arrive: immediately by ``start``
        # (static work list), by the feeder thread's exit for ``start_stream``
        self._feed_done = threading.Event()
        self._feeder: Optional[threading.Thread] = None
        self._monitor: Optional[threading.Thread] = None
        self.items_done = 0
        self.peak_workers = n_workers

    @classmethod
    def from_plan(cls, plan, client, **kwargs) -> "DPPWorkerPool":
        """Pool over a spec-compiled ``repro.dpp.worker.WorkerPlan`` instead
        of a hand-wired worker factory (the declarative read path's entry)."""
        from repro.dpp.worker import DPPWorker

        return cls(lambda: DPPWorker.from_plan(plan), client, **kwargs)

    # -- worker loop -------------------------------------------------------------
    def _worker_loop(self, worker) -> None:
        t0 = time.perf_counter()
        try:
            while True:
                with self._lock:
                    if self._retire > 0:
                        self._retire -= 1
                        return  # cooperative shrink: retire this thread
                try:
                    item = self._items.get(timeout=0.05)
                except queue.Empty:
                    if self._feed_done.is_set():
                        return  # stream over AND queue drained
                    continue    # live feed: stay parked for the next item
                if self.jagged and hasattr(worker, "process_jagged"):
                    out = worker.process_jagged(item)
                    if out is not None:   # None = worker dropped every example
                        self.client.put_jagged(out)
                else:
                    out = worker.process(item)
                    if out is not None:
                        self.client.put(out)
                with self._lock:
                    self.items_done += 1
        except BaseException as e:
            with self._lock:
                self._errors.append(e)
        finally:
            with self._lock:
                self._live -= 1
            worker.stats.total_time_s += time.perf_counter() - t0

    def _resize_to(self, target: int) -> None:
        """Grow by spawning threads; shrink by issuing retirement tokens."""
        with self._lock:
            logical = self._live - self._retire
            if target > logical:
                for _ in range(target - logical):
                    worker = self.worker_factory()
                    th = threading.Thread(target=self._worker_loop,
                                          args=(worker,), daemon=True)
                    self._workers.append(worker)
                    self._threads.append(th)
                    self._live += 1
                    th.start()
            elif target < logical:
                self._retire += logical - target
            self.peak_workers = max(self.peak_workers, target)

    def current_workers(self) -> int:
        with self._lock:
            return max(0, self._live - self._retire)

    # -- elasticity ---------------------------------------------------------------
    def _busy_time_total(self) -> float:
        with self._lock:
            workers = list(self._workers)
        return sum(w.stats.busy_time_s for w in workers)

    def _monitor_loop(self) -> None:
        """Feed WINDOWED starvation/waste to the controller: lifetime
        aggregates ratchet — one slow warmup step (jit compile) would read as
        permanent starvation, growing to max_workers and never shrinking
        (the shrink branch needs a starvation-free WINDOW, which a cumulative
        counter can never show again after its first recorded wait)."""
        last_starved = self.client.stats.starved_time_s
        last_train = self.client.stats.train_time_s
        last_busy = self._busy_time_total()
        last_t = time.perf_counter()
        while not self._done.wait(self.control_interval_s):
            if self._feed_done.is_set() and self._items.empty():
                return
            s = self.client.stats
            now = time.perf_counter()
            d_starved = s.starved_time_s - last_starved
            d_train = s.train_time_s - last_train
            busy = self._busy_time_total()
            d_busy = busy - last_busy
            d_wall = (now - last_t) * max(self.current_workers(), 1)
            last_starved, last_train, last_busy, last_t = (
                s.starved_time_s, s.train_time_s, busy, now)
            denom = d_starved + d_train
            starvation = 100.0 * d_starved / denom if denom > 0 else 0.0
            waste = max(0.0, 1.0 - d_busy / d_wall) * 100.0 if d_wall > 0 \
                else 0.0
            new = self.controller.decide(self.current_workers(), starvation,
                                         waste)
            self._resize_to(new)

    # -- API ---------------------------------------------------------------------
    def start(self, items: Sequence[List]) -> "DPPWorkerPool":
        """Dispatch a STATIC work list; workers exit once it is drained."""
        for item in items:
            self._items.put(item)
        self._feed_done.set()
        self._start_threads()
        return self

    def start_stream(self, items: Iterable[List],
                     max_buffered: int = 0) -> "DPPWorkerPool":
        """Dispatch a LIVE item source (e.g. ``StreamingSource.micro_batches``):
        a feeder thread pulls items as they become available and workers stay
        parked across idle gaps; they exit only when the source is exhausted
        AND the queue is drained. A feeder failure is re-raised from
        ``join()`` like any worker error.

        ``max_buffered`` > 0 bounds the item queue, applying backpressure to
        the source — without it a fast producer (e.g. a warehouse backfill
        replay) would buffer its entire output in memory ahead of the
        workers."""
        if max_buffered > 0:
            # workers have not started yet; swapping the queue is safe
            self._items = queue.Queue(maxsize=max_buffered)

        def feeder() -> None:
            try:
                for item in items:
                    while True:
                        # NO live workers + recorded errors = the pool died:
                        # stop feeding (checked per attempt, not just on
                        # queue.Full, so an unbounded queue doesn't keep
                        # consuming the source for nobody), or join() (and
                        # the client close that unblocks the trainer) would
                        # wait on this feeder forever
                        with self._lock:
                            dead = self._live == 0 and bool(self._errors)
                        if dead:
                            return
                        try:
                            self._items.put(item, timeout=0.1)
                            break
                        except queue.Full:
                            continue
            except BaseException as e:
                with self._lock:
                    self._errors.append(e)
            finally:
                self._feed_done.set()

        self._feeder = threading.Thread(target=feeder, daemon=True,
                                        name="dpp-feeder")
        self._feeder.start()
        self._start_threads()
        return self

    def _start_threads(self) -> None:
        self._resize_to(self._n_initial)
        if self.controller is not None:
            self._monitor = threading.Thread(target=self._monitor_loop,
                                             daemon=True)
            self._monitor.start()

    def _join_workers(self) -> None:
        while True:
            with self._lock:
                alive = [t for t in self._threads if t.is_alive()]
            if not alive:
                return
            for t in alive:
                t.join()

    @property
    def errors(self) -> List[BaseException]:
        with self._lock:
            return list(self._errors)

    def join(self) -> None:
        try:
            # workers first: if they ALL died on errors while the feeder is
            # parked on a full bounded queue, the feeder's dead-pool check
            # needs the worker exits to have landed before it can abort
            self._join_workers()
            if self._feeder is not None:
                while self._feeder.is_alive():
                    self._feeder.join(timeout=0.1)
                    if self._feeder.is_alive():
                        with self._lock:
                            dead = self._live == 0 and bool(self._errors)
                        if dead:
                            # the feeder may be parked INSIDE the source
                            # iterator (idle-open stream) where no dead-pool
                            # check can run: abandon the daemon thread so the
                            # client close + error re-raise below still happen
                            break
            self._join_workers()
            self._done.set()
            if self._monitor is not None:
                self._monitor.join()
            self._join_workers()   # monitor may have spawned a final thread
        finally:
            # close EVEN ON worker failure: the consumer must receive the
            # end-of-stream sentinel or it blocks forever on a dead feed
            # (the raise below reaches join's caller, not the trainer)
            if self.close_client:
                self.client.close()
        if self._errors:
            raise RuntimeError(
                f"{len(self._errors)} DPP worker(s) failed") from self._errors[0]

    def run(self, items: Sequence[List]) -> "DPPWorkerPool":
        """Blocking convenience: dispatch ``items``, wait, close the client.

        The client's buffer must be drained concurrently (or sized to hold the
        whole stream) or workers block on the bounded slot queue."""
        self.start(items)
        self.join()
        return self

    def merged_worker_stats(self):
        """Aggregate per-thread WorkerStats into one job-level view."""
        from repro.dpp.worker import WorkerStats

        out = WorkerStats()
        with self._lock:
            workers = list(self._workers)
        for w in workers:
            s = w.stats
            out.base_batches += s.base_batches
            out.examples += s.examples
            out.probe_time_s += s.probe_time_s
            out.lookup_time_s += s.lookup_time_s
            out.featurize_time_s += s.featurize_time_s
            out.total_time_s += s.total_time_s
            out.dedup_hits += s.dedup_hits
            out.decode_cache_hits += s.decode_cache_hits
            out.parallel_shards += s.parallel_shards
        return out


class StragglerAwarePool:
    """Thread pool with deadline-based speculative re-dispatch.

    Work items are idempotent (materialization is a pure read), so running a
    straggler's item twice is safe — first completion wins.
    """

    def __init__(
        self,
        work_fn: Callable[[object], object],
        n_workers: int = 2,
        straggler_deadline_s: float = 5.0,
    ):
        self.work_fn = work_fn
        self.straggler_deadline_s = straggler_deadline_s
        self._task_q: "queue.Queue" = queue.Queue()
        self._done: Dict[int, object] = {}
        self._done_cv = threading.Condition()
        self._inflight: Dict[int, float] = {}   # task id -> dispatch time
        self._retried: set = set()
        self._stop = threading.Event()
        self.stats = PoolStats()
        self._threads: List[threading.Thread] = []
        self.resize(n_workers)

    # -- worker loop -------------------------------------------------------------
    def _loop(self, me: int) -> None:
        while not self._stop.is_set():
            try:
                task_id, payload = self._task_q.get(timeout=0.05)
            except queue.Empty:
                continue
            with self._done_cv:
                if task_id in self._done:   # speculative duplicate already done
                    continue
                self._inflight[task_id] = time.perf_counter()
            try:
                result = self.work_fn(payload)
            except Exception:
                self.stats.worker_failures += 1
                # crash-equivalent: re-queue the item for another worker
                self._task_q.put((task_id, payload))
                continue
            with self._done_cv:
                if task_id not in self._done:
                    self._done[task_id] = result
                    self.stats.completed += 1
                self._inflight.pop(task_id, None)
                self._done_cv.notify_all()

    # -- API ---------------------------------------------------------------------
    def submit(self, task_id: int, payload: object) -> None:
        self._task_q.put((task_id, payload))

    def _respeculate(self, pending_payloads: Dict[int, object]) -> None:
        now = time.perf_counter()
        with self._done_cv:
            for tid, started in list(self._inflight.items()):
                if (
                    now - started > self.straggler_deadline_s
                    and tid not in self._retried
                    and tid in pending_payloads
                ):
                    self._retried.add(tid)
                    self.stats.speculative_retries += 1
                    self._task_q.put((tid, pending_payloads[tid]))

    def gather(self, task_ids, payloads: Dict[int, object], timeout_s: float = 60.0):
        """Wait for all task_ids, re-dispatching stragglers as needed."""
        deadline = time.perf_counter() + timeout_s
        while True:
            with self._done_cv:
                if all(t in self._done for t in task_ids):
                    return [self._done[t] for t in task_ids]
                self._done_cv.wait(timeout=0.05)
            self._respeculate(payloads)
            if time.perf_counter() > deadline:
                raise TimeoutError("pool gather timed out")

    def resize(self, n_workers: int) -> None:
        while len(self._threads) < n_workers:
            t = threading.Thread(target=self._loop, args=(len(self._threads),),
                                 daemon=True)
            t.start()
            self._threads.append(t)
        # shrink is cooperative: extra threads exit when stop is set; for the
        # simulation we only record the logical size
        self.n_workers = n_workers

    def shutdown(self) -> None:
        self._stop.set()
        for t in self._threads:
            t.join(timeout=1.0)
