"""Collective-byte accounting from post-SPMD optimized HLO text.

``cost_analysis()`` does not report collective traffic, so we parse the
compiled module: every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute contributes link bytes per chip according to the standard
ring-algorithm cost model:

  all-gather       out_bytes * (n-1)/n
  reduce-scatter   out_bytes * (n-1)          (input = n * output per device)
  all-reduce       2 * bytes * (n-1)/n
  all-to-all       bytes * (n-1)/n
  collective-permute  bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s+(?P<result>\([^)]*\)|[\w\[\],{}]+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    result_bytes: Dict[str, int]     # sum of per-device result sizes
    link_bytes: float                # ring-model bytes over ICI per chip

    def to_dict(self):
        return dataclasses.asdict(self)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    result_bytes: Dict[str, int] = {}
    link = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or "-done(" in line:   # count -start once, skip -done
            continue
        op = m.group("op")
        nbytes = _shape_bytes(m.group("result"))
        # group size n
        n = 0
        g = _GROUPS_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            g2 = _GROUPS_V2_RE.search(line)
            if g2:
                n = int(g2.group(2))
        n = max(n, 2)
        counts[op] = counts.get(op, 0) + 1
        result_bytes[op] = result_bytes.get(op, 0) + nbytes
        frac = (n - 1) / n
        if op == "all-reduce":
            link += 2.0 * nbytes * frac
        elif op == "all-gather":
            link += nbytes * frac
        elif op == "reduce-scatter":
            link += nbytes * (n - 1)
        elif op == "all-to-all":
            link += nbytes * frac
        else:  # collective-permute
            link += nbytes
    return CollectiveStats(counts, result_bytes, link)
